"""Selection serving: a coalescing front door over the batched engines.

This is the serving shape for submodular subset selection (the paper's
engine is single-node, one query at a time): clients submit selection
requests — :class:`~repro.core.optimizers.spec.SelectionSpec` objects, the
same typed request the whole library runs on — and the server answers them
in **waves**:

  submit()  ->  per-(family, n-bucket) pending queue  [continuous batching]
  flush()   ->  drain queues into padded waves
            ->  one batched-engine dispatch per wave
                  (single device, or a 2-D batch x data mesh via ``mesh=``)
            ->  demultiplex per-request responses + structured metrics

Requests queue **per group** (the coalescer's :func:`~repro.launch.coalesce.
group_key`, computed shape-only at submit time), so a front end can flush
one hot group the moment it fills while a cold group keeps waiting for
co-travellers — the continuous-batching discipline LLM servers use, applied
to selection waves.  ``submit`` applies **backpressure**: when ``max_queue``
requests are already pending, it raises :class:`ServerOverloaded` instead
of letting the queue grow without bound.  Specs may carry a ``deadline_s``;
the async front end flushes a group early to honor the earliest deadline,
and responses report whether theirs was missed.

Failure discipline: a mid-flush engine error raises :class:`FlushError`
carrying the exact partition of the work — already-computed responses are
re-held for the next flush, never-dispatched requests are re-enqueued at
the front of their queues, and only the poisoned wave's requests are named
as failed (and also re-enqueued by ``flush()``, so the caller can ``cancel``
them or retry).  Nothing is ever dropped.

Results are bit-identical to a loop of single ``maximize`` calls per request
(``tests/test_serving.py`` pins this): zero-padding adds zero-gain
candidates that the ``valid`` mask blocks, budget bucketing only extends the
frozen tail of the greedy loop, and the sharded path preserves the
sweep -> first-argmax -> update ordering exactly.  Per-group queueing only
changes *when* a wave dispatches, never what rides it.

    # 8 host devices, 2x2 batch x data mesh, a mixed random workload:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.serve --requests 32 --mesh 2x2

See docs/serving.md for the request lifecycle and knob table,
``launch/metrics.py`` for the metrics schema, and benchmarks/serve_bench.py
for the wave-size x mesh-shape throughput sweep.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Optional, Sequence

import numpy as np

from repro.core.optimizers.backends import backend_name
from repro.core.optimizers.batched import BatchedEngine
from repro.core.optimizers.spec import (
    SelectionSpec,
    resolve_optimizer,
    wave_capable_names,
)
from repro.launch import faults
from repro.launch.coalesce import (
    SelectionRequest,
    Wave,
    group_key,
    group_label,
    waves_for_group,
)
from repro.launch.metrics import ServerMetrics
from repro.launch.resilience import (
    SINGLE_ATTEMPT,
    BreakerBoard,
    RequestFailed,
    RetryPolicy,
)


class ServerOverloaded(RuntimeError):
    """``submit`` refused: the server already holds ``max_queue`` pending
    requests.  Retry after a flush drains the queue, raise ``max_queue``, or
    (async front end) submit with ``block=True`` to wait for space."""


class FlushError(RuntimeError):
    """An engine dispatch failed mid-flush.

    Carries the exact partition of the flush's work so no request and no
    computed response is ever lost:

    - ``completed``: {rid: response} for waves that finished BEFORE the
      failure (``flush()`` re-holds these for its next call);
    - ``failed_requests``: the poisoned wave's requests (``flush()``
      re-enqueues them at the front of their queue — ``cancel(rid)`` them
      before retrying if the poison is the request itself);
    - ``undispatched_requests``: requests whose waves never ran
      (``flush()`` re-enqueues them, original arrival stamps intact).

    ``__cause__`` is the engine's original exception.
    """

    def __init__(
        self,
        message: str,
        *,
        completed: dict,
        failed_requests: list,
        undispatched_requests: list,
    ):
        super().__init__(message)
        self.completed = completed
        self.failed_requests = failed_requests
        self.undispatched_requests = undispatched_requests

    @property
    def failed_rids(self) -> list:
        return [r.rid for r in self.failed_requests]

    @property
    def undispatched_rids(self) -> list:
        return [r.rid for r in self.undispatched_requests]


@dataclasses.dataclass
class SelectionResponse:
    """Answer to one request, plus where/how it was served.

    Latency accounting is truthful and decomposed: ``queue_s`` is how long
    THIS request waited for co-travellers (submit -> its wave's dispatch
    start), ``wave_s`` is the wave's dispatch wall time (shared by the
    wave), and ``latency_s`` is their sum — what the client observed.  A
    request that waited 500 ms for a 10 ms wave reports 510 ms, not 10.
    """

    rid: int | str
    selection: list  # [(index, gain), ...] in pick order, true-n index space
    result: object  # the per-request GreedyResult (== sequential solve)
    wave_size: int  # real requests in the wave that served this
    n_bucket: int  # padded ground-set size of that wave
    backend: str  # gain-sweep backend that answered ("xla", "pallas-fl", ...)
    latency_s: float  # client-observed: queue_s + wave_s
    queue_s: float = 0.0  # submit -> wave dispatch start (this request's wait)
    wave_s: float = 0.0  # wave dispatch wall time (shared by the wave)
    deadline_missed: bool = False  # delivered after the spec's deadline_s
    attempts: int = 1  # dispatch attempts this request survived (retries + 1)
    degraded: str | None = None  # "xla" / "single-device" when a breaker
    #   rerouted the wave off its primary backend or mesh (results are still
    #   bit-identical to sequential solve — only the implementation changed)


class ServerStats:
    """Aggregate accounting across flushes — a bounded-memory view over
    :class:`~repro.launch.metrics.ServerMetrics`.

    Replaces the old unbounded ``wave_seconds`` list: totals are exact
    (count / sum / max), percentiles come from a fixed-size reservoir, so a
    long-lived server's accounting is O(1) in flush count.  ``summary()``
    keeps the historical keys (requests / waves / slots / padded_slots /
    total_s / qps) and adds the latency-decomposition and backpressure
    fields; ``snapshot()`` is the full structured tree.
    """

    def __init__(self, metrics: ServerMetrics | None = None):
        self.metrics = metrics if metrics is not None else ServerMetrics()

    @property
    def requests(self) -> int:
        return self.metrics.counters["requests"]

    @property
    def waves(self) -> int:
        return self.metrics.counters["waves"]

    @property
    def slots(self) -> int:  # total engine slots dispatched (incl. batch pads)
        return self.metrics.counters["slots"]

    @property
    def padded_slots(self) -> int:  # batch-pad slots (wasted work)
        return self.metrics.counters["padded_slots"]

    @property
    def rejections(self) -> int:  # submits refused by backpressure
        return self.metrics.counters["rejections"]

    @property
    def total_seconds(self) -> float:
        return float(self.metrics.wave_s.total)

    @property
    def qps(self) -> float:
        t = self.total_seconds
        return self.requests / t if t > 0 else 0.0

    def summary(self) -> dict:
        m = self.metrics
        return {
            "requests": self.requests,
            "waves": self.waves,
            "slots": self.slots,
            "padded_slots": self.padded_slots,
            "total_s": round(self.total_seconds, 4),
            "qps": round(self.qps, 1),
            "wave_p50_s": round(m.wave_s.percentile(0.50), 4) if self.waves else 0.0,
            "wave_p99_s": round(m.wave_s.percentile(0.99), 4) if self.waves else 0.0,
            "queue_p50_s": round(m.queue_s.percentile(0.50), 4)
            if m.queue_s.count
            else 0.0,
            "queue_p99_s": round(m.queue_s.percentile(0.99), 4)
            if m.queue_s.count
            else 0.0,
            "rejections": self.rejections,
            "deadline_misses": m.counters["deadline_misses"],
            "retries_total": m.counters["retries_total"],
            "fallbacks_total": m.counters["fallbacks_total"],
            "quarantined_total": m.counters["quarantined_total"],
            "breaker_state": dict(sorted(m.breaker_states.items())),
        }

    def snapshot(self) -> dict:
        """The full structured metric tree (see launch/metrics.py schema)."""
        return self.metrics.snapshot()


class SelectionServer:
    """Per-group coalescing selection server over :class:`BatchedEngine`.

    Args:
      mesh: None for single-device serving, or a 2-D mesh whose
        ``batch_axis`` shards the wave's batch dimension and ``data_axis``
        shards every instance's candidate axis (the distributed batched
        engine).  Wave padding automatically rounds up to the mesh axis
        sizes.
      max_wave: cap on real requests per wave (bounds per-wave latency).
      max_queue: admission-control cap on TOTAL pending requests across all
        group queues; ``submit`` raises :class:`ServerOverloaded` beyond it.
        None (default) disables backpressure.
      retry_policy: server-wide default :class:`~repro.launch.resilience.
        RetryPolicy`.  When it is set — or any pending spec carries its own
        ``retry`` — ``flush()`` switches to the resilient path: transient
        wave failures are retried with backoff, the poison request is
        isolated into a singleton wave so it cannot re-poison its group,
        and exhausted requests resolve to typed
        :class:`~repro.launch.resilience.RequestFailed` entries
        (``take_failures()``) instead of aborting the flush.  A request's
        ``spec.retry`` always wins over the server default.  With neither
        set, ``flush()`` keeps the legacy single-attempt
        :class:`FlushError` contract exactly.
      breakers: a :class:`~repro.launch.resilience.BreakerBoard` (one is
        created when omitted).  Dispatch consults ``(family, "kernel")``
        before running a fused backend and ``(family, "mesh")`` before a
        mesh dispatch; an open breaker reroutes the wave degraded —
        Pallas -> XLA via ``use_kernel=False``, mesh -> single device —
        which stays bit-identical to sequential ``solve()``.

    The dispatch path is synchronous; ``submit`` only enqueues (into the
    request's group queue — the coalescer's wave identity promoted to queue
    identity).  The async front-end that flushes each group on its own
    depth / timer / deadline triggers and completes futures is
    :class:`repro.launch.async_serve.AsyncSelectionServer`; it drives this
    server through ``drain`` / ``dispatch_waves`` so its lock never covers
    an engine dispatch.
    """

    def __init__(
        self,
        mesh=None,
        batch_axis: str = "batch",
        data_axis: str = "data",
        max_wave: int = 64,
        max_queue: int | None = None,
        retry_policy: RetryPolicy | None = None,
        breakers: BreakerBoard | None = None,
    ):
        self.mesh = mesh
        self.batch_axis = batch_axis
        self.data_axis = data_axis
        self.max_wave = max_wave
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1 or None, got {max_queue}")
        self.max_queue = max_queue
        if retry_policy is not None and not isinstance(retry_policy, RetryPolicy):
            raise TypeError(
                f"retry_policy must be a RetryPolicy or None, "
                f"got {type(retry_policy).__name__!r}"
            )
        self.retry_policy = retry_policy
        self.breakers = breakers if breakers is not None else BreakerBoard()
        if mesh is not None:
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            for name in (batch_axis, data_axis):
                if name not in sizes:
                    raise ValueError(
                        f"mesh has no axis {name!r} (axes: {mesh.axis_names})"
                    )
            self.b_multiple = sizes[batch_axis]
            self.n_multiple = sizes[data_axis]
        else:
            self.b_multiple = 1
            self.n_multiple = 1
        # group_key -> FIFO of SelectionRequests (insertion-ordered dict, so
        # flush order follows each group's first arrival)
        self._queues: dict[tuple, list[SelectionRequest]] = {}
        self._undelivered: dict = {}  # flushed but not yet returned to a caller
        self._failures: dict = {}  # rid -> RequestFailed, not yet taken
        self._attempts: dict = {}  # rid -> [attempt dicts] across retries
        self._next_rid = 0
        self._dispatch_seq = 0  # 0-based dispatch ordinal (fault addressing)
        self.metrics = ServerMetrics()
        self.stats = ServerStats(self.metrics)
        self.breakers.bind(self.metrics.set_breaker)

    # -- request ingest ------------------------------------------------------

    @property
    def pending_count(self) -> int:
        """Total pending requests across all group queues."""
        return sum(len(q) for q in self._queues.values())

    def submit_spec(self, spec: SelectionSpec, rid=None):
        """Enqueue one validated :class:`SelectionSpec` into its group's
        queue; returns its request id.

        Everything that could poison a flush is rejected HERE, at submit
        time, so a bad request can never abort the flush that would have
        answered everyone else's:

        - an unsupported function family (no registered padder) raises
          ``NotImplementedError`` naming ``register_padder``;
        - an optimizer without batched execution hooks (e.g.
          StochasticGreedy) raises ``ValueError`` naming the batched-capable
          set;
        - a full server (``max_queue`` pending) raises
          :class:`ServerOverloaded` — admission control, counted under
          ``rejections``.

        Unknown optimizer names, misspelled hyperparameters, and family
        stop-rule defaults were already handled when the spec was built —
        requests are specs, so serving adds no second validation dialect.
        """
        from repro.launch.coalesce import resolve_padder

        if not isinstance(spec, SelectionSpec):
            raise TypeError(
                f"submit_spec() takes a SelectionSpec, got {type(spec).__name__!r}"
            )
        resolve_padder(type(spec.fn))  # raises NotImplementedError if unsupported
        defn = resolve_optimizer(spec.optimizer.name)
        if not defn.batched_capable:
            raise ValueError(
                f"optimizer {spec.optimizer.name!r} has no batched execution "
                f"hooks, so it cannot ride served waves; batched-capable "
                f"optimizers: {wave_capable_names()}"
            )
        if self.max_queue is not None and self.pending_count >= self.max_queue:
            self.metrics.inc("rejections")
            raise ServerOverloaded(
                f"pending queue is full ({self.pending_count}/{self.max_queue} "
                f"requests); flush, raise max_queue, or retry after a drain"
            )
        if rid is None:
            rid = self._next_rid
            self._next_rid += 1
        req = SelectionRequest(rid=rid, spec=spec)
        key = group_key(req, n_multiple=self.n_multiple)
        queue = self._queues.setdefault(key, [])
        queue.append(req)
        self.metrics.observe_enqueue(
            group_label(req, n_multiple=self.n_multiple), len(queue)
        )
        return rid

    def submit(
        self,
        request,
        budget: int | None = None,
        optimizer: str | None = None,
        rid=None,
        **kwargs,
    ):
        """Enqueue one selection request; returns its request id.

        The request is a :class:`SelectionSpec` (the typed path —
        equivalent to :meth:`submit_spec`).  The legacy
        ``submit(fn, budget, optimizer=..., stopIfZeroGain=..., screen_k=...)``
        form is deprecated: it builds the spec for you (family stop-rule
        defaults — e.g. Disparity*'s ``stopIfZeroGain=False`` — now resolve
        inside :class:`SelectionSpec`, so sequential and served execution
        agree) and emits a ``DeprecationWarning``.
        """
        if isinstance(request, SelectionSpec):
            if budget is not None or optimizer is not None or kwargs:
                raise TypeError(
                    "submit(spec) takes no extra options — budget, optimizer "
                    "and stop rules already live on the SelectionSpec"
                )
            return self.submit_spec(request, rid=rid)
        from repro.core.optimizers.api import _warn_shim

        _warn_shim(
            "SelectionServer.submit(fn, budget, ...)",
            "SelectionServer.submit(SelectionSpec(fn, budget, ...))",
        )
        spec = SelectionSpec(
            request,
            budget,
            "NaiveGreedy" if optimizer is None else optimizer,
            stopIfZeroGain=kwargs.pop("stopIfZeroGain", None),
            stopIfNegativeGain=kwargs.pop("stopIfNegativeGain", None),
            **kwargs,
        )
        return self.submit_spec(spec, rid=rid)

    def open_session(self, spec: SelectionSpec, *, sid=None, journal=None):
        """Open a long-lived :class:`~repro.launch.sessions.SelectionSession`
        around ``spec``: feed ground-set deltas with ``extend(features=...)``
        / ``extend(indices=...)`` and get the refreshed selection after each.
        Deltas ride the normal per-group queues (same coalescing, same
        backpressure), so every update is bit-identical to a direct
        ``solve()`` over the stream so far.  Pass a
        :class:`~repro.launch.sessions.SessionJournal` (and optionally a
        stable ``sid``) to journal committed deltas for crash recovery via
        :func:`~repro.launch.sessions.restore_sessions`."""
        from repro.launch.sessions import SelectionSession

        return SelectionSession(self, spec, sid=sid, journal=journal)

    def cancel(self, rid) -> bool:
        """Remove one pending request (or one undelivered response) by id.
        Returns True if something was removed.  The escape hatch after a
        :class:`FlushError` named a poisoned request as failed: cancel it
        and re-flush the survivors."""
        for key, queue in list(self._queues.items()):
            for i, req in enumerate(queue):
                if req.rid == rid:
                    del queue[i]
                    if not queue:
                        del self._queues[key]
                    return True
        return self._undelivered.pop(rid, None) is not None

    def group_states(self) -> list[tuple]:
        """Scheduling view of the pending queues: one
        ``(group_key, depth, oldest_enqueue_t, earliest_deadline_t)`` tuple
        per non-empty group (``earliest_deadline_t`` is None when no member
        carries a deadline).  The async front end's flush triggers read
        this; it is also handy for dashboards."""
        out = []
        for key, queue in self._queues.items():
            deadlines = [t for t in (r.deadline_t for r in queue) if t is not None]
            out.append(
                (
                    key,
                    len(queue),
                    queue[0].enqueue_t,
                    min(deadlines) if deadlines else None,
                )
            )
        return out

    # -- dispatch ------------------------------------------------------------

    def _dispatch(self, wave: Wave) -> dict:
        fam = type(wave.requests[0].spec.fn).__name__
        widx = self._dispatch_seq
        self._dispatch_seq += 1
        # bookkeeping probe: the wave's PRIMARY backend, for breaker routing
        # and fault addressing — suspended so it never consumes fault budget
        with faults.suspended():
            primary = backend_name(wave.fns[0])
        fns, mesh = wave.fns, self.mesh
        kernel_degraded = mesh_degraded = False
        if primary != "xla" and not self.breakers.allow((fam, "kernel")):
            # open kernel breaker: reroute Pallas -> XLA.  use_kernel is a
            # static meta field, so replace() only changes the trace-time
            # backend choice — results stay bit-identical (pinned parity).
            fns = [dataclasses.replace(f, use_kernel=False) for f in fns]
            kernel_degraded = True
        if mesh is not None and not self.breakers.allow((fam, "mesh")):
            mesh = None  # open mesh breaker: serve single-device
            mesh_degraded = True
        degraded = "+".join(
            label
            for flag, label in (
                (kernel_degraded, "xla"),
                (mesh_degraded, "single-device"),
            )
            if flag
        ) or None
        if degraded is not None:
            self.metrics.inc("fallbacks_total")
        t0 = time.monotonic()
        try:
            faults.check(
                "dispatch",
                family=fam,
                backend=primary,
                wave_index=widx,
                mesh=mesh is not None,
                rids=tuple(r.rid for r in wave.requests),
                label=wave.label,
            )
            # host-side backend resolution doubles as the "kernel" fault
            # boundary (resolve_backend); also names the backend that
            # actually answers after any degraded rewrite
            name = backend_name(fns[0])
            engine = BatchedEngine(
                fns,
                valid=wave.valid,
                mesh=mesh,
                batch_axis=self.batch_axis,
                data_axis=self.data_axis,
            )
            results = engine.run(
                wave.budgets,
                wave.optimizer,
                stop_if_zero=wave.stop_if_zero,
                stop_if_negative=wave.stop_if_negative,
                max_budget=wave.max_budget,
            )
        except Exception as e:
            # attribute the failure to the path that was actually in play:
            # kernel-site faults (and any error while a fused backend was
            # live) charge the kernel breaker; dispatch errors on a mesh
            # charge the mesh breaker
            site = getattr(e, "site", None)
            if site == "kernel":
                self.breakers.record_failure((fam, "kernel"))
            elif mesh is not None:
                self.breakers.record_failure((fam, "mesh"))
            elif primary != "xla" and not kernel_degraded:
                self.breakers.record_failure((fam, "kernel"))
            raise
        if primary != "xla" and not kernel_degraded:
            self.breakers.record_success((fam, "kernel"))
        if mesh is not None:
            self.breakers.record_success((fam, "mesh"))
        t1 = time.monotonic()
        wave_s = t1 - t0
        label = wave.label
        self.metrics.observe_wave(
            label,
            wave_s,
            requests=len(wave.requests),
            slots=wave.batch_size,
            padded_slots=wave.n_padded_slots,
        )
        by_rid = wave.demux(results)
        out = {}
        for req in wave.requests:
            queue_s = max(0.0, t0 - req.enqueue_t)
            missed = req.deadline_t is not None and t1 > req.deadline_t
            self.metrics.observe_served(label, queue_s, deadline_missed=missed)
            out[req.rid] = SelectionResponse(
                rid=req.rid,
                selection=by_rid[req.rid].as_list(),
                result=by_rid[req.rid],
                wave_size=len(wave.requests),
                n_bucket=wave.n_bucket,
                backend=name,
                latency_s=queue_s + wave_s,
                queue_s=queue_s,
                wave_s=wave_s,
                deadline_missed=missed,
                degraded=degraded,
            )
        return out

    def drain(
        self, keys: Optional[Sequence[tuple]] = None, *, take_undelivered: bool = True
    ) -> tuple[list[Wave], dict]:
        """Atomically remove pending requests and build their waves.

        Args:
          keys: group keys to drain (default: every non-empty group).  This
            is the continuous-batching hook — a front end drains just the
            groups whose own trigger fired.
          take_undelivered: also take (and clear) the held responses from
            earlier partial flushes; ``flush()`` wants them, the async front
            end leaves them for the sync caller.

        Returns ``(waves, undelivered)``.  ALL waves are built before any
        queue entry is removed, so a wave-build error leaves the server
        state fully intact (nothing half-drained).
        """
        if keys is None:
            keys = list(self._queues)
        waves: list[Wave] = []
        for key in keys:
            requests = self._queues.get(key)
            if not requests:
                continue
            waves.extend(
                waves_for_group(
                    requests,
                    max_wave=self.max_wave,
                    n_multiple=self.n_multiple,
                    b_multiple=self.b_multiple,
                )
            )
        for key in keys:
            self._queues.pop(key, None)
        undelivered: dict = {}
        if take_undelivered:
            undelivered, self._undelivered = self._undelivered, {}
        return waves, undelivered

    def dispatch_waves(self, waves: Sequence[Wave]) -> dict:
        """Dispatch already-built waves in order; returns {rid: response}.

        Pure compute — touches no queues, so it is safe to call OUTSIDE any
        lock guarding them.  On an engine error it raises
        :class:`FlushError` carrying the exact work partition (completed
        responses / failed wave / undispatched waves); the caller decides
        how to re-hold and re-enqueue.
        """
        responses: dict = {}
        for i, wave in enumerate(waves):
            try:
                responses.update(self._dispatch(wave))
            except Exception as e:
                self.metrics.inc("flush_errors")
                undispatched = [r for w in waves[i + 1 :] for r in w.requests]
                failed = list(wave.requests)
                raise FlushError(
                    f"wave {i + 1}/{len(waves)} ({wave.label}, "
                    f"{len(failed)} requests: {[r.rid for r in failed]}) "
                    f"failed: {e}; {len(responses)} completed responses held, "
                    f"{len(undispatched)} undispatched requests preserved",
                    completed=responses,
                    failed_requests=failed,
                    undispatched_requests=undispatched,
                ) from e
        return responses

    def requeue(self, requests: Sequence[SelectionRequest]) -> None:
        """Put drained-but-unserved requests back at the FRONT of their
        group queues, original arrival stamps intact (so queue-time
        accounting spans the failure, truthfully)."""
        for req in reversed(list(requests)):
            key = group_key(req, n_multiple=self.n_multiple)
            self._queues.setdefault(key, []).insert(0, req)
        if requests:
            self.metrics.inc("requeued", len(requests))

    # -- resilience ----------------------------------------------------------

    def _resilience_active(self) -> bool:
        """True when flushes should run the retry/quarantine path: a
        server-wide ``retry_policy``, or any pending spec carrying its own
        ``retry``.  With neither, flush keeps the legacy single-attempt
        :class:`FlushError` contract."""
        if self.retry_policy is not None:
            return True
        return any(
            req.spec.retry is not None
            for queue in self._queues.values()
            for req in queue
        )

    def _policy_for(self, req: SelectionRequest) -> RetryPolicy:
        """The request's effective policy: its spec's, else the server's,
        else single-attempt (fail typed on first error, no retry)."""
        if req.spec.retry is not None:
            return req.spec.retry
        if self.retry_policy is not None:
            return self.retry_policy
        return SINGLE_ATTEMPT

    def _note_attempt(self, req: SelectionRequest, error) -> RequestFailed | None:
        """Charge one failed attempt against ``req``'s budget.  Returns the
        terminal :class:`RequestFailed` when the budget is exhausted —
        ``max_attempts`` (``"quarantined"``) or wall-clock ``timeout_s``
        (``"timeout"``) — else None (the request may retry)."""
        now = time.monotonic()
        hist = self._attempts.setdefault(req.rid, [])
        hist.append(
            {
                "attempt": len(hist) + 1,
                "error": f"{type(error).__name__}: {error}",
                "elapsed_s": round(max(0.0, now - req.enqueue_t), 6),
            }
        )
        pol = self._policy_for(req)
        if pol.timeout_s is not None and now - req.enqueue_t >= pol.timeout_s:
            reason = "timeout"
        elif len(hist) >= pol.max_attempts:
            reason = "quarantined"
            self.metrics.inc("quarantined_total")
        else:
            return None
        self._attempts.pop(req.rid, None)
        return RequestFailed(req.rid, reason, hist, cause=error)

    def _isolate(self, req: SelectionRequest, failures: dict) -> Wave | None:
        """Rebuild ``req`` as a singleton wave for a retry.  Build (padder)
        errors are charged against its attempt budget like any other; on
        exhaustion the terminal failure lands in ``failures`` and None is
        returned."""
        while True:
            try:
                return waves_for_group(
                    [req],
                    max_wave=1,
                    n_multiple=self.n_multiple,
                    b_multiple=self.b_multiple,
                )[0]
            except Exception as e:
                self.metrics.inc("flush_errors")
                term = self._note_attempt(req, e)
                if term is not None:
                    failures[req.rid] = term
                    return None
                self.metrics.inc("retries_total")
                wait = self._policy_for(req).backoff(
                    len(self._attempts[req.rid]), seed=req.rid
                )
                if wait > 0:
                    time.sleep(wait)

    def dispatch_resilient(self, waves: Sequence[Wave]) -> tuple[dict, dict]:
        """Dispatch waves with per-request retry, poison isolation, and
        typed quarantine; returns ``(responses, failures)`` — every drained
        rid resolves into exactly one of the two dicts, and no exception
        escapes for a wave failure.

        On a wave failure each rider is charged one attempt: exhausted
        requests fail typed (:class:`RequestFailed` in ``failures``), the
        rest retry — a multi-request wave is rebuilt as singleton waves
        first, so the one poison request cannot re-poison its co-travellers
        (they succeed alone on the next attempt).  Backoff between attempts
        follows each request's policy with jitter seeded by its rid, so
        reruns back off identically.  Like :meth:`dispatch_waves` this
        touches no queues and is safe outside any queue lock.
        """
        responses: dict = {}
        failures: dict = {}
        pending: list[Wave] = list(waves)
        while pending:
            wave = pending.pop(0)
            try:
                out = self._dispatch(wave)
            except Exception as e:
                self.metrics.inc("flush_errors")
                retryable = []
                for req in wave.requests:
                    term = self._note_attempt(req, e)
                    if term is not None:
                        failures[req.rid] = term
                    else:
                        retryable.append(req)
                if not retryable:
                    continue
                self.metrics.inc("retries_total", len(retryable))
                if len(wave.requests) > 1:
                    # poison isolation: each survivor retries ALONE
                    rebuilt = []
                    for req in retryable:
                        w = self._isolate(req, failures)
                        if w is not None:
                            rebuilt.append(w)
                    pending[:0] = rebuilt
                else:
                    pending.insert(0, wave)  # already a singleton
                live = [r for r in retryable if r.rid in self._attempts]
                if live:
                    wait = max(
                        self._policy_for(r).backoff(
                            len(self._attempts[r.rid]), seed=r.rid
                        )
                        for r in live
                    )
                    if wait > 0:
                        time.sleep(wait)
                continue
            for req in wave.requests:
                prior = self._attempts.pop(req.rid, None)
                if prior:
                    out[req.rid].attempts = len(prior) + 1
            responses.update(out)
        return responses, failures

    def drain_resilient(
        self, keys: Optional[Sequence[tuple]] = None, *, take_undelivered: bool = True
    ) -> tuple[list[Wave], dict, dict, float]:
        """Like :meth:`drain`, but a wave-build (padder) error costs ONE
        group instead of aborting the whole drain, and requests whose
        wall-clock ``timeout_s`` already lapsed are reaped before any build.

        Returns ``(waves, undelivered, failures, retry_wait)``:
        ``failures`` maps reaped/exhausted rids to :class:`RequestFailed`;
        a group whose build failed keeps its retryable requests QUEUED and
        reports the backoff to wait before re-draining via ``retry_wait``
        (this method never sleeps — the async front end calls it under its
        lock).
        """
        if keys is None:
            keys = list(self._queues)
        waves: list[Wave] = []
        failures: dict = {}
        retry_wait = 0.0
        for key in list(keys):
            requests = self._queues.get(key)
            if not requests:
                self._queues.pop(key, None)
                continue
            now = time.monotonic()
            live = []
            for req in requests:
                pol = self._policy_for(req)
                if pol.timeout_s is not None and now - req.enqueue_t >= pol.timeout_s:
                    hist = self._attempts.pop(req.rid, [])
                    failures[req.rid] = RequestFailed(req.rid, "timeout", hist)
                else:
                    live.append(req)
            if not live:
                self._queues.pop(key, None)
                continue
            try:
                group_waves = waves_for_group(
                    live,
                    max_wave=self.max_wave,
                    n_multiple=self.n_multiple,
                    b_multiple=self.b_multiple,
                )
            except Exception as e:
                self.metrics.inc("flush_errors")
                keep = []
                for req in live:
                    term = self._note_attempt(req, e)
                    if term is not None:
                        failures[req.rid] = term
                    else:
                        keep.append(req)
                if keep:
                    self.metrics.inc("retries_total", len(keep))
                    self._queues[key] = keep
                    retry_wait = max(
                        retry_wait,
                        max(
                            self._policy_for(r).backoff(
                                len(self._attempts[r.rid]), seed=r.rid
                            )
                            for r in keep
                        ),
                    )
                else:
                    self._queues.pop(key, None)
                continue
            waves.extend(group_waves)
            self._queues.pop(key, None)
        undelivered: dict = {}
        if take_undelivered:
            undelivered, self._undelivered = self._undelivered, {}
        return waves, undelivered, failures, retry_wait

    def take_failures(self) -> dict:
        """Hand over (and clear) the typed failures from resilient flushes:
        ``{rid: RequestFailed}``.  Each failure is delivered exactly once —
        callers own what they take."""
        out, self._failures = self._failures, {}
        return out

    def hold_failures(self, failures: dict) -> None:
        """Re-hold typed failures for a later :meth:`take_failures` — the
        async front end stashes failures for rids owned by the sync flush
        path here, mirroring :meth:`hold_undelivered`."""
        self._failures.update(failures)

    def _flush_resilient(self) -> dict:
        """The resilient flush body: rounds of drain + dispatch until every
        queue is empty.  Groups whose build failed retryably stay queued
        between rounds (backoff honored here, outside any lock); every
        drained rid ends as exactly one response (returned) or one
        :class:`RequestFailed` (held for :meth:`take_failures`)."""
        responses: dict = {}
        failures: dict = {}
        first = True
        while True:
            waves, undelivered, drain_failures, retry_wait = self.drain_resilient(
                take_undelivered=first
            )
            first = False
            responses.update(undelivered)
            failures.update(drain_failures)
            if waves:
                out, dispatch_failures = self.dispatch_resilient(waves)
                responses.update(out)
                failures.update(dispatch_failures)
            if not any(self._queues.values()):
                break
            if retry_wait > 0:
                time.sleep(retry_wait)
        if failures:
            self.hold_failures(failures)
        return responses

    def flush(self) -> dict:
        """Drain every group + dispatch; returns {rid: response}, including
        any responses computed by an earlier ``select`` call on behalf of
        requests it didn't own (nothing is ever dropped).

        On a mid-flush engine error, raises :class:`FlushError` AFTER
        restoring the server to a no-loss state: completed responses (this
        flush's and previously-held ones) are re-held for the next call,
        and every unserved request — the failed wave's and the
        never-dispatched ones — is re-enqueued at the front of its queue.
        ``e.failed_rids`` names the poisoned wave; ``cancel`` those before
        retrying if the requests themselves are at fault.

        When a :class:`~repro.launch.resilience.RetryPolicy` is in play
        (server-wide or on any pending spec) this switches to the resilient
        path instead: transient failures retry with backoff, the poison
        request is isolated, and exhausted requests resolve to typed
        failures via :meth:`take_failures` — :class:`FlushError` is never
        raised.
        """
        if self._resilience_active():
            return self._flush_resilient()
        waves, responses = self.drain()
        try:
            responses.update(self.dispatch_waves(waves))
        except FlushError as e:
            responses.update(e.completed)
            self.hold_undelivered(responses)
            # front-of-queue order: failed wave ahead of the undispatched
            # tail, matching original arrival order
            self.requeue(e.undispatched_requests)
            self.requeue(e.failed_requests)
            raise
        return responses

    def hold_undelivered(self, responses: dict) -> None:
        """Re-hold already-computed responses for delivery by a later
        ``flush()``.  Used by callers that drain ``flush()`` on behalf of a
        subset of requests (``select``, the async front end) so responses to
        everyone else's requests are never dropped."""
        self._undelivered.update(responses)

    def select(self, requests: Sequence) -> list[SelectionResponse]:
        """Convenience: submit specs — or (fn, budget) pairs, which become
        ``SelectionSpec(fn, budget)`` with family defaults — flush, and
        return responses in request order.  Responses to requests enqueued
        earlier via ``submit`` ride the same flush and are held for the next
        ``flush`` call."""
        specs = [
            r if isinstance(r, SelectionSpec) else SelectionSpec(r[0], r[1])
            for r in requests
        ]
        rids = [self.submit_spec(s) for s in specs]
        out = self.flush()
        mine = [out.pop(r) for r in rids]
        self.hold_undelivered(out)
        return mine


# ---------------------------------------------------------------------------
# CLI: serve a random mixed workload and report throughput.
# ---------------------------------------------------------------------------

# dispersion families: the empty-set gain is 0.  SelectionSpec's per-family
# default table already sets stopIfZeroGain=False for them; the CLI
# additionally disables stopIfNegativeGain so long-budget requests keep
# selecting past the point where adding an element shrinks the dispersion
# objective
DISPERSION_FAMILIES = frozenset({"dsum", "dmin"})


def _random_function(kind: str, n: int, rng):
    """One random instance of a served family (shared by tests/benchmarks)."""
    from repro.core import (
        GCMI,
        FLQMI,
        FacilityLocation,
        FeatureBased,
        GraphCut,
        LogDet,
        ProbabilisticSetCover,
        SetCover,
        create_kernel,
    )

    def kernel():
        x = rng.normal(size=(n, 8)).astype(np.float32)
        return np.asarray(create_kernel(x, metric="euclidean"))

    if kind == "fl":
        return FacilityLocation.from_kernel(kernel())
    if kind == "gc":
        return GraphCut.from_kernel(kernel(), lam=0.3)
    if kind == "fb":
        feats = rng.uniform(0, 1, size=(n, 12)).astype(np.float32)
        return FeatureBased.from_features(feats, concave="sqrt")
    if kind == "sc":
        cover = rng.integers(0, 2, size=(n, 16)).astype(np.float32)
        return SetCover.from_cover(cover)
    if kind == "psc":
        probs = rng.uniform(0, 0.9, size=(n, 16)).astype(np.float32)
        return ProbabilisticSetCover.from_probs(probs)
    if kind == "dsum":
        from repro.core import DisparitySum

        return DisparitySum.from_distance(1.0 - kernel())
    if kind == "dmin":
        from repro.core import DisparityMin

        return DisparityMin.from_distance(1.0 - kernel())
    if kind == "flqmi":
        x = rng.normal(size=(n, 8)).astype(np.float32)
        q = rng.normal(size=(6, 8)).astype(np.float32)
        from repro.core import create_kernel as ck

        return FLQMI.build(np.asarray(ck(q, x, metric="euclidean")))
    if kind == "gcmi":
        x = rng.normal(size=(n, 8)).astype(np.float32)
        q = rng.normal(size=(5, 8)).astype(np.float32)
        from repro.core import create_kernel as ck

        return GCMI.build(np.asarray(ck(x, q, metric="euclidean")), lam=0.4)
    if kind == "logdet":
        S = kernel() + 0.5 * np.eye(n, dtype=np.float32)
        return LogDet.from_kernel(S, max_select=16)
    raise KeyError(kind)


def _random_requests(
    n_requests: int, seed: int = 0, families: Sequence[str] = ("fl", "gc", "fb")
):
    """A mixed workload with varying n, cycling through ``families`` (any of
    fl / gc / fb / sc / psc / dsum / dmin / flqmi / gcmi / logdet — every
    family here has a padder AND a ShardRule, so the workload serves on and
    off mesh; dsum/dmin requests get stopIfZeroGain=False by default at
    submit time, see :meth:`SelectionServer.submit`)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        n = int(rng.choice([24, 32, 48, 64]))
        budget = int(rng.integers(3, 9))
        fn = _random_function(families[i % len(families)], n, rng)
        reqs.append((fn, budget))
    return reqs


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument(
        "--mesh",
        default=None,
        help="BATCHxDATA device grid, e.g. 2x2 (requires "
        "XLA_FLAGS=--xla_force_host_platform_device_count=N on CPU); "
        "default: single-device serving",
    )
    ap.add_argument("--max-wave", type=int, default=64)
    ap.add_argument(
        "--max-queue",
        type=int,
        default=None,
        help="backpressure cap on pending requests (default: unbounded)",
    )
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--families",
        default="fl,gc,fb",
        help="comma-separated families to mix into the workload "
        "(fl,gc,fb,sc,psc,flqmi,gcmi,logdet)",
    )
    ap.add_argument(
        "--metrics",
        action="store_true",
        help="print the full structured metrics snapshot (JSON) at the end",
    )
    a = ap.parse_args()

    import jax

    mesh = None
    if a.mesh:
        b, d = (int(v) for v in a.mesh.lower().split("x"))
        mesh = jax.make_mesh((b, d), ("batch", "data"))

    server = SelectionServer(mesh=mesh, max_wave=a.max_wave, max_queue=a.max_queue)
    families = tuple(a.families.split(","))
    requests = _random_requests(a.requests, seed=a.seed, families=families)
    # same family indexing as _random_requests: dispersion requests ride with
    # stopping disabled, otherwise their selections are silently empty
    kinds = [families[i % len(families)] for i in range(len(requests))]

    for rnd in range(a.rounds):
        t0 = time.perf_counter()
        rids = [
            server.submit(
                SelectionSpec(
                    fn,
                    budget,
                    # the family table already defaults stopIfZeroGain=False
                    # for dispersion; the CLI additionally disables the
                    # negative-gain stop so long-budget dispersion requests
                    # keep selecting
                    stopIfNegativeGain=kind not in DISPERSION_FAMILIES,
                )
            )
            for (fn, budget), kind in zip(requests, kinds)
        ]
        out = server.flush()
        responses = [out[r] for r in rids]
        dt = time.perf_counter() - t0
        assert len(responses) == len(requests)
        assert all(r.selection for r in responses), "empty selection served"
        label = "warmup (compiles)" if rnd == 0 else "steady"
        print(
            f"round {rnd}: {len(requests)} requests in {dt:.3f}s "
            f"({len(requests) / dt:.1f} q/s)  [{label}]"
        )

    s = server.stats.summary()
    print(f"\nserver stats: {s}")
    r0 = responses[0]
    print(
        f"sample response: rid={r0.rid} wave={r0.wave_size} "
        f"n_bucket={r0.n_bucket} backend={r0.backend} "
        f"queue={r0.queue_s * 1e3:.2f}ms wave={r0.wave_s * 1e3:.2f}ms "
        f"latency={r0.latency_s * 1e3:.2f}ms "
        f"selection={[i for i, _ in r0.selection]}"
    )
    if a.metrics:
        print(json.dumps(server.stats.snapshot(), indent=2))


if __name__ == "__main__":
    main()
