"""Selection serving: a coalescing front door over the batched engines.

This is the serving shape for submodular subset selection (the paper's
engine is single-node, one query at a time): clients submit selection
requests — :class:`~repro.core.optimizers.spec.SelectionSpec` objects, the
same typed request the whole library runs on — and the server answers them
in **waves**:

  submit()  ->  pending queue
  flush()   ->  coalesce into padded (function-family, n-bucket) waves
            ->  one batched-engine dispatch per wave
                  (single device, or a 2-D batch x data mesh via ``mesh=``)
            ->  demultiplex per-request responses + latency/throughput stats

Results are bit-identical to a loop of single ``maximize`` calls per request
(``tests/test_serving.py`` pins this): zero-padding adds zero-gain
candidates that the ``valid`` mask blocks, budget bucketing only extends the
frozen tail of the greedy loop, and the sharded path preserves the
sweep -> first-argmax -> update ordering exactly.

    # 8 host devices, 2x2 batch x data mesh, a mixed random workload:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.serve --requests 32 --mesh 2x2

See docs/serving.md for the request lifecycle and benchmarks/serve_bench.py
for the wave-size x mesh-shape throughput sweep.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Sequence

import numpy as np

from repro.core.optimizers.backends import backend_name
from repro.core.optimizers.batched import BatchedEngine
from repro.core.optimizers.spec import (
    SelectionSpec,
    resolve_optimizer,
    wave_capable_names,
)
from repro.launch.coalesce import SelectionRequest, Wave, coalesce


@dataclasses.dataclass
class SelectionResponse:
    """Answer to one request, plus where/how it was served."""

    rid: int | str
    selection: list  # [(index, gain), ...] in pick order, true-n index space
    result: object  # the per-request GreedyResult (n_evals counts padded n)
    wave_size: int  # real requests in the wave that served this
    n_bucket: int  # padded ground-set size of that wave
    backend: str  # gain-sweep backend that answered ("xla", "pallas-fl", ...)
    latency_s: float  # wave dispatch wall time (shared by the wave)


@dataclasses.dataclass
class ServerStats:
    """Aggregate accounting across flushes."""

    requests: int = 0
    waves: int = 0
    slots: int = 0  # total engine slots dispatched (incl. batch pads)
    padded_slots: int = 0  # batch-pad slots (wasted work)
    wave_seconds: list = dataclasses.field(default_factory=list)

    @property
    def total_seconds(self) -> float:
        return float(sum(self.wave_seconds))

    @property
    def qps(self) -> float:
        t = self.total_seconds
        return self.requests / t if t > 0 else 0.0

    def summary(self) -> dict:
        return {
            "requests": self.requests,
            "waves": self.waves,
            "slots": self.slots,
            "padded_slots": self.padded_slots,
            "total_s": round(self.total_seconds, 4),
            "qps": round(self.qps, 1),
        }


class SelectionServer:
    """Coalescing selection server over :class:`BatchedEngine`.

    Args:
      mesh: None for single-device serving, or a 2-D mesh whose
        ``batch_axis`` shards the wave's batch dimension and ``data_axis``
        shards every instance's candidate axis (the distributed batched
        engine).  Wave padding automatically rounds up to the mesh axis
        sizes.
      max_wave: cap on real requests per wave (bounds per-wave latency).

    The dispatch path is synchronous; ``submit`` only enqueues.  The async
    front-end that flushes on timer / queue-depth triggers and completes
    futures from the returned dict is
    :class:`repro.launch.async_serve.AsyncSelectionServer`.
    """

    def __init__(
        self,
        mesh=None,
        batch_axis: str = "batch",
        data_axis: str = "data",
        max_wave: int = 64,
    ):
        self.mesh = mesh
        self.batch_axis = batch_axis
        self.data_axis = data_axis
        self.max_wave = max_wave
        if mesh is not None:
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            for name in (batch_axis, data_axis):
                if name not in sizes:
                    raise ValueError(
                        f"mesh has no axis {name!r} (axes: {mesh.axis_names})"
                    )
            self.b_multiple = sizes[batch_axis]
            self.n_multiple = sizes[data_axis]
        else:
            self.b_multiple = 1
            self.n_multiple = 1
        self._pending: list[SelectionRequest] = []
        self._undelivered: dict = {}  # flushed but not yet returned to a caller
        self._next_rid = 0
        self.stats = ServerStats()

    # -- request ingest ------------------------------------------------------

    def submit_spec(self, spec: SelectionSpec, rid=None):
        """Enqueue one validated :class:`SelectionSpec`; returns its request
        id.

        Everything that could poison a flush is rejected HERE, at submit
        time, so a bad request can never abort the flush that would have
        answered everyone else's:

        - an unsupported function family (no registered padder) raises
          ``NotImplementedError`` naming ``register_padder``;
        - an optimizer without batched execution hooks (e.g.
          StochasticGreedy) raises ``ValueError`` naming the batched-capable
          set.

        Unknown optimizer names, misspelled hyperparameters, and family
        stop-rule defaults were already handled when the spec was built —
        requests are specs, so serving adds no second validation dialect.
        """
        from repro.launch.coalesce import resolve_padder

        if not isinstance(spec, SelectionSpec):
            raise TypeError(
                f"submit_spec() takes a SelectionSpec, got {type(spec).__name__!r}"
            )
        resolve_padder(type(spec.fn))  # raises NotImplementedError if unsupported
        defn = resolve_optimizer(spec.optimizer.name)
        if not defn.batched_capable:
            raise ValueError(
                f"optimizer {spec.optimizer.name!r} has no batched execution "
                f"hooks, so it cannot ride served waves; batched-capable "
                f"optimizers: {wave_capable_names()}"
            )
        if rid is None:
            rid = self._next_rid
            self._next_rid += 1
        self._pending.append(SelectionRequest(rid=rid, spec=spec))
        return rid

    def submit(
        self,
        request,
        budget: int | None = None,
        optimizer: str | None = None,
        rid=None,
        **kwargs,
    ):
        """Enqueue one selection request; returns its request id.

        The request is a :class:`SelectionSpec` (the typed path —
        equivalent to :meth:`submit_spec`).  The legacy
        ``submit(fn, budget, optimizer=..., stopIfZeroGain=..., screen_k=...)``
        form is deprecated: it builds the spec for you (family stop-rule
        defaults — e.g. Disparity*'s ``stopIfZeroGain=False`` — now resolve
        inside :class:`SelectionSpec`, so sequential and served execution
        agree) and emits a ``DeprecationWarning``.
        """
        if isinstance(request, SelectionSpec):
            if budget is not None or optimizer is not None or kwargs:
                raise TypeError(
                    "submit(spec) takes no extra options — budget, optimizer "
                    "and stop rules already live on the SelectionSpec"
                )
            return self.submit_spec(request, rid=rid)
        from repro.core.optimizers.api import _warn_shim

        _warn_shim(
            "SelectionServer.submit(fn, budget, ...)",
            "SelectionServer.submit(SelectionSpec(fn, budget, ...))",
        )
        spec = SelectionSpec(
            request,
            budget,
            "NaiveGreedy" if optimizer is None else optimizer,
            stopIfZeroGain=kwargs.pop("stopIfZeroGain", None),
            stopIfNegativeGain=kwargs.pop("stopIfNegativeGain", None),
            **kwargs,
        )
        return self.submit_spec(spec, rid=rid)

    # -- dispatch ------------------------------------------------------------

    def _dispatch(self, wave: Wave) -> dict:
        t0 = time.perf_counter()
        engine = BatchedEngine(
            wave.fns,
            valid=wave.valid,
            mesh=self.mesh,
            batch_axis=self.batch_axis,
            data_axis=self.data_axis,
        )
        results = engine.run(
            wave.budgets,
            wave.optimizer,
            stop_if_zero=wave.stop_if_zero,
            stop_if_negative=wave.stop_if_negative,
            max_budget=wave.max_budget,
        )
        dt = time.perf_counter() - t0
        self.stats.waves += 1
        self.stats.requests += len(wave.requests)
        self.stats.slots += wave.batch_size
        self.stats.padded_slots += wave.n_padded_slots
        self.stats.wave_seconds.append(dt)
        name = backend_name(wave.fns[0])
        by_rid = wave.demux(results)
        return {
            req.rid: SelectionResponse(
                rid=req.rid,
                selection=by_rid[req.rid].as_list(),
                result=by_rid[req.rid],
                wave_size=len(wave.requests),
                n_bucket=wave.n_bucket,
                backend=name,
                latency_s=dt,
            )
            for req in wave.requests
        }

    def flush(self) -> dict:
        """Coalesce + dispatch everything pending; returns {rid: response},
        including any responses computed by an earlier ``select`` call on
        behalf of requests it didn't own (nothing is ever dropped).
        Coalescing runs BEFORE the pending queue and undelivered-response
        holders are cleared, so a coalesce-time error leaves the server
        state intact instead of silently dropping everyone's requests."""
        waves = coalesce(
            self._pending,
            max_wave=self.max_wave,
            n_multiple=self.n_multiple,
            b_multiple=self.b_multiple,
        )
        self._pending = []
        responses, self._undelivered = self._undelivered, {}
        for wave in waves:
            responses.update(self._dispatch(wave))
        return responses

    def hold_undelivered(self, responses: dict) -> None:
        """Re-hold already-computed responses for delivery by a later
        ``flush()``.  Used by callers that drain ``flush()`` on behalf of a
        subset of requests (``select``, the async front end) so responses to
        everyone else's requests are never dropped."""
        self._undelivered.update(responses)

    def select(self, requests: Sequence) -> list[SelectionResponse]:
        """Convenience: submit specs — or (fn, budget) pairs, which become
        ``SelectionSpec(fn, budget)`` with family defaults — flush, and
        return responses in request order.  Responses to requests enqueued
        earlier via ``submit`` ride the same flush and are held for the next
        ``flush`` call."""
        specs = [
            r if isinstance(r, SelectionSpec) else SelectionSpec(r[0], r[1])
            for r in requests
        ]
        rids = [self.submit_spec(s) for s in specs]
        out = self.flush()
        mine = [out.pop(r) for r in rids]
        self.hold_undelivered(out)
        return mine


# ---------------------------------------------------------------------------
# CLI: serve a random mixed workload and report throughput.
# ---------------------------------------------------------------------------

# dispersion families: the empty-set gain is 0.  SelectionSpec's per-family
# default table already sets stopIfZeroGain=False for them; the CLI
# additionally disables stopIfNegativeGain so long-budget requests keep
# selecting past the point where adding an element shrinks the dispersion
# objective
DISPERSION_FAMILIES = frozenset({"dsum", "dmin"})


def _random_function(kind: str, n: int, rng):
    """One random instance of a served family (shared by tests/benchmarks)."""
    from repro.core import (
        GCMI,
        FLQMI,
        FacilityLocation,
        FeatureBased,
        GraphCut,
        LogDet,
        ProbabilisticSetCover,
        SetCover,
        create_kernel,
    )

    def kernel():
        x = rng.normal(size=(n, 8)).astype(np.float32)
        return np.asarray(create_kernel(x, metric="euclidean"))

    if kind == "fl":
        return FacilityLocation.from_kernel(kernel())
    if kind == "gc":
        return GraphCut.from_kernel(kernel(), lam=0.3)
    if kind == "fb":
        feats = rng.uniform(0, 1, size=(n, 12)).astype(np.float32)
        return FeatureBased.from_features(feats, concave="sqrt")
    if kind == "sc":
        cover = rng.integers(0, 2, size=(n, 16)).astype(np.float32)
        return SetCover.from_cover(cover)
    if kind == "psc":
        probs = rng.uniform(0, 0.9, size=(n, 16)).astype(np.float32)
        return ProbabilisticSetCover.from_probs(probs)
    if kind == "dsum":
        from repro.core import DisparitySum

        return DisparitySum.from_distance(1.0 - kernel())
    if kind == "dmin":
        from repro.core import DisparityMin

        return DisparityMin.from_distance(1.0 - kernel())
    if kind == "flqmi":
        x = rng.normal(size=(n, 8)).astype(np.float32)
        q = rng.normal(size=(6, 8)).astype(np.float32)
        from repro.core import create_kernel as ck

        return FLQMI.build(np.asarray(ck(q, x, metric="euclidean")))
    if kind == "gcmi":
        x = rng.normal(size=(n, 8)).astype(np.float32)
        q = rng.normal(size=(5, 8)).astype(np.float32)
        from repro.core import create_kernel as ck

        return GCMI.build(np.asarray(ck(x, q, metric="euclidean")), lam=0.4)
    if kind == "logdet":
        S = kernel() + 0.5 * np.eye(n, dtype=np.float32)
        return LogDet.from_kernel(S, max_select=16)
    raise KeyError(kind)


def _random_requests(
    n_requests: int, seed: int = 0, families: Sequence[str] = ("fl", "gc", "fb")
):
    """A mixed workload with varying n, cycling through ``families`` (any of
    fl / gc / fb / sc / psc / dsum / dmin / flqmi / gcmi / logdet — every
    family here has a padder AND a ShardRule, so the workload serves on and
    off mesh; dsum/dmin requests get stopIfZeroGain=False by default at
    submit time, see :meth:`SelectionServer.submit`)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        n = int(rng.choice([24, 32, 48, 64]))
        budget = int(rng.integers(3, 9))
        fn = _random_function(families[i % len(families)], n, rng)
        reqs.append((fn, budget))
    return reqs


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument(
        "--mesh",
        default=None,
        help="BATCHxDATA device grid, e.g. 2x2 (requires "
        "XLA_FLAGS=--xla_force_host_platform_device_count=N on CPU); "
        "default: single-device serving",
    )
    ap.add_argument("--max-wave", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--families",
        default="fl,gc,fb",
        help="comma-separated families to mix into the workload "
        "(fl,gc,fb,sc,psc,flqmi,gcmi,logdet)",
    )
    a = ap.parse_args()

    import jax

    mesh = None
    if a.mesh:
        b, d = (int(v) for v in a.mesh.lower().split("x"))
        mesh = jax.make_mesh((b, d), ("batch", "data"))

    server = SelectionServer(mesh=mesh, max_wave=a.max_wave)
    families = tuple(a.families.split(","))
    requests = _random_requests(a.requests, seed=a.seed, families=families)
    # same family indexing as _random_requests: dispersion requests ride with
    # stopping disabled, otherwise their selections are silently empty
    kinds = [families[i % len(families)] for i in range(len(requests))]

    for rnd in range(a.rounds):
        t0 = time.perf_counter()
        rids = [
            server.submit(
                SelectionSpec(
                    fn,
                    budget,
                    # the family table already defaults stopIfZeroGain=False
                    # for dispersion; the CLI additionally disables the
                    # negative-gain stop so long-budget dispersion requests
                    # keep selecting
                    stopIfNegativeGain=kind not in DISPERSION_FAMILIES,
                )
            )
            for (fn, budget), kind in zip(requests, kinds)
        ]
        out = server.flush()
        responses = [out[r] for r in rids]
        dt = time.perf_counter() - t0
        assert len(responses) == len(requests)
        assert all(r.selection for r in responses), "empty selection served"
        label = "warmup (compiles)" if rnd == 0 else "steady"
        print(
            f"round {rnd}: {len(requests)} requests in {dt:.3f}s "
            f"({len(requests) / dt:.1f} q/s)  [{label}]"
        )

    s = server.stats.summary()
    print(f"\nserver stats: {s}")
    r0 = responses[0]
    print(
        f"sample response: rid={r0.rid} wave={r0.wave_size} "
        f"n_bucket={r0.n_bucket} backend={r0.backend} "
        f"selection={[i for i, _ in r0.selection]}"
    )


if __name__ == "__main__":
    main()
