"""Batched serving engine: continuous prefill+decode over a request queue.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --requests 8 --prompt-len 64 --gen-len 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models.model import decode_step, init_params, prefill


class ServeEngine:
    """Static-batch serving: prefill a batch of prompts, then decode greedily.

    The decode step is jit'd once per (batch, max_len) bucket — the same
    program the dry-run lowers for decode_32k/long_500k."""

    def __init__(self, cfg, params, max_len: int):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._decode = jax.jit(
            lambda p, c, t, n: decode_step(cfg, p, c, t, n), donate_argnums=1
        )
        self._prefill = jax.jit(
            lambda p, b: prefill(cfg, p, b, max_len=max_len)
        )

    def generate(self, batch: dict, gen_len: int):
        B, L = batch["tokens"].shape
        logits, caches = self._prefill(self.params, batch)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        out = [tok]
        for i in range(gen_len - 1):
            logits, caches = self._decode(
                self.params, caches, tok, jnp.asarray(L + i, jnp.int32)
            )
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            out.append(tok)
        return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--full", action="store_true")
    a = ap.parse_args()

    cfg = get_config(a.arch)
    if not a.full:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (a.requests, a.prompt_len)), jnp.int32
        )
    }
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(a.requests, cfg.enc_positions, cfg.d_model)), jnp.float32
        )
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(a.requests, cfg.n_patches, cfg.d_model)), jnp.float32
        )

    engine = ServeEngine(cfg, params, a.prompt_len + a.gen_len)
    t0 = time.time()
    tokens = engine.generate(batch, a.gen_len)
    dt = time.time() - t0
    total = a.requests * a.gen_len
    print(f"generated {tokens.shape} in {dt:.2f}s  ({total / dt:.1f} tok/s)")
    print("sample:", np.asarray(tokens[0][:16]))


if __name__ == "__main__":
    main()
