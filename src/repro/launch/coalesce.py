"""Request coalescing for the selection server (launch/serve.py).

Incoming selection requests are heterogeneous — different function families,
ground-set sizes, budgets — while the batched engines want homogeneous,
statically-shaped waves.  This module is the bridge:

1. **pad**: each request's function is zero-padded along the candidate axis
   to a power-of-two bucket size (zero rows/columns have zero marginal gain
   for the supported families, so padding never changes the selection), with
   a per-request ``valid`` row masking the padding;
2. **group**: padded requests sharing (pytree structure, leaf shapes,
   optimizer, stop flags) coalesce into waves of at most ``max_wave``;
3. **bucket budgets**: a wave's static loop bound is the power-of-two bucket
   of its largest budget, so waves with different budget mixes reuse one
   compiled program (instances freeze once their own budget is spent);
4. **pad the batch**: when serving on a mesh, the wave's batch dimension is
   padded to a multiple of the batch-axis size with budget-0 copies of the
   first instance (they finish in zero steps and are dropped at demux).

The demultiplexing inverse lives on :class:`Wave`: results come back in wave
order and :meth:`Wave.demux` maps them to request ids, dropping batch pads.

Padding semantics are family-specific and registered in ``_PADDERS`` —
FacilityLocation (zero COLUMNS only: the represented-set rows are never
padded, because appending rows changes XLA's sum-reduction tree and shifts
gains by ulps — see ``_pad_fl``), GraphCut (zero rows+columns, zero modular
term — its gains are elementwise, so both axes pad exactly), FeatureBased
(zero feature rows; the feature axis is untouched), SetCover /
ProbabilisticSetCover (zero incidence rows; the concept axis is untouched),
DisparitySum / DisparityMin (zero rows+columns — padded candidates are
valid-masked and padded columns are never selected), LogDet (zero
rows+columns: a padded candidate's Cholesky pivot is 0, so its gain is
NEG_INF), GCMI (zero query-sum entries), and the FL-family information
measures (zero COLUMNS of the ground-side kernel only; the query-side row
axis is never padded, for the same reduction-tree reason as FL).  MI / CG
measures that are plain instances of a padded family — gccg, sc_mi/.../
psc_cmi, logdet_cg — resolve along the MRO and need no entry of their own.
The matrix-free families (FacilityLocationMF / GraphCutMF) pad their
similarity SOURCE instead of a matrix: feature sources pad zero feature
rows on the candidate axis, k-NN sources pad meta-only for FL (the scatter
target grows) and -1/-0 rows for GC, dense sources pad like their
materialized counterparts — so feature- and k-NN-backed requests serve
through ``solve()`` / ``SelectionServer`` unchanged.
``register_padder`` plugs in more families; unsupported ones raise a
``NotImplementedError`` naming it (see docs/functions.md for the coverage
matrix).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

import jax
import numpy as np

from repro.core.functions.disparity import DisparityMin, DisparitySum
from repro.core.functions.facility_location import FacilityLocation, FacilityLocationMF
from repro.core.functions.feature_based import FeatureBased
from repro.core.functions.graph_cut import GraphCut, GraphCutMF
from repro.core.functions.log_det import LogDet
from repro.core.functions.set_cover import ProbabilisticSetCover, SetCover
from repro.core.info.fl import FLCG, FLCMI, FLQMI, FLVMI
from repro.core.info.gc import GCMI
from repro.core.optimizers.spec import OptimizerSpec, SelectionSpec
from repro.core.sources import DenseSource, FeatureSource, KnnSource
from repro.launch import faults


@dataclasses.dataclass
class SelectionRequest:
    """One enqueued query: a request id plus its :class:`SelectionSpec`.

    The request IS the spec — serving adds only routing identity (``rid``)
    and arrival time (``enqueue_t``, monotonic, stamped at construction),
    which is what lets the coalescer, the batched engines, and the async
    front end all consume the same validated object unchanged.  The arrival
    stamp is what makes latency accounting truthful: a response reports the
    time the *client* waited (queue + dispatch), not just its wave's
    dispatch wall time.
    """

    rid: int | str
    spec: SelectionSpec
    enqueue_t: float = dataclasses.field(default_factory=time.monotonic)

    @property
    def fn(self):
        """The function with the spec's backend choice applied."""
        return self.spec.resolved_fn()

    @property
    def budget(self) -> int:
        return self.spec.budget

    @property
    def deadline_t(self) -> Optional[float]:
        """Absolute monotonic deadline (``enqueue_t + spec.deadline_s``), or
        None when the request carries no deadline."""
        if self.spec.deadline_s is None:
            return None
        return self.enqueue_t + self.spec.deadline_s


def next_pow2(x: int) -> int:
    return 1 if x <= 1 else 1 << (int(x) - 1).bit_length()


def bucket_size(n: int, multiple: int = 1) -> int:
    """Power-of-two bucket >= n, rounded up to a multiple (the mesh data-axis
    size, so sharded waves always divide evenly)."""
    b = next_pow2(n)
    return -(-b // multiple) * multiple


# ---------------------------------------------------------------------------
# Family padders: fn, n_to -> equivalent instance over a padded ground set.
# ---------------------------------------------------------------------------

def _pad_fl(fn: FacilityLocation, n_to: int) -> FacilityLocation:
    import jax.numpy as jnp

    U, n = fn.sim.shape
    # zero columns only: a padded candidate's gain is max(0 - curmax, 0) = 0
    # and the valid mask blocks it.  The ROW (represented-set) axis is never
    # padded — a gain is a sum-reduction over rows, and appending even exact
    # zeros changes XLA's reduction tree and therefore the float result by
    # ulps; keeping rows intact is what makes served FL selections
    # bit-identical to unpadded `maximize`.  Requests with different row
    # counts simply land in different waves (the wave key includes shapes).
    sim = jnp.zeros((U, n_to), fn.sim.dtype).at[:, :n].set(fn.sim)
    return FacilityLocation(sim=sim, n=n_to, use_kernel=fn.use_kernel)


def _pad_gc(fn: GraphCut, n_to: int) -> GraphCut:
    import jax.numpy as jnp

    n = fn.n
    sim = jnp.zeros((n_to, n_to), fn.sim_ground.dtype).at[:n, :n].set(fn.sim_ground)
    total = jnp.zeros((n_to,), fn.total.dtype).at[:n].set(fn.total)
    return GraphCut(
        sim_ground=sim, total=total, lam=fn.lam, n=n_to, use_kernel=fn.use_kernel
    )


def _pad_fb(fn: FeatureBased, n_to: int) -> FeatureBased:
    import jax.numpy as jnp

    n = fn.n
    feats = jnp.zeros((n_to, fn.feats.shape[1]), fn.feats.dtype).at[:n].set(fn.feats)
    return FeatureBased(
        feats=feats, w=fn.w, n=n_to, concave=fn.concave, use_kernel=fn.use_kernel
    )


def _pad_sc(fn: SetCover, n_to: int) -> SetCover:
    import jax.numpy as jnp

    n, m = fn.cover.shape
    # zero incidence rows: a padded candidate covers nothing, so its gain is
    # exactly 0 and the valid mask blocks it; real candidates' gains are
    # per-row reductions over the untouched concept axis, so they are
    # bit-identical to the unpadded instance.
    cover = jnp.zeros((n_to, m), fn.cover.dtype).at[:n].set(fn.cover)
    return SetCover(cover=cover, w=fn.w, n=n_to, use_kernel=fn.use_kernel)


def _pad_psc(fn: ProbabilisticSetCover, n_to: int) -> ProbabilisticSetCover:
    import jax.numpy as jnp

    n, m = fn.log_miss.shape
    # log(1 - p) = 0 rows: a padded candidate has p = 0 everywhere -> gain 0.
    log_miss = jnp.zeros((n_to, m), fn.log_miss.dtype).at[:n].set(fn.log_miss)
    return ProbabilisticSetCover(
        log_miss=log_miss, w=fn.w, n=n_to, use_kernel=fn.use_kernel
    )


def _pad_square_dist(fn, n_to: int):
    import jax.numpy as jnp

    cls = type(fn)
    n = fn.n
    dist = jnp.zeros((n_to, n_to), fn.dist.dtype).at[:n, :n].set(fn.dist)
    return cls(dist=dist, n=n_to, use_kernel=fn.use_kernel)


def _pad_logdet(fn: LogDet, n_to: int) -> LogDet:
    import jax.numpy as jnp

    n = fn.n
    # zero rows+columns: a padded candidate's pivot d2 starts (and stays) 0,
    # so its gain is NEG_INF and it can never be selected even before the
    # valid mask; max_select is preserved (it is capacity, not ground size).
    L = jnp.zeros((n_to, n_to), fn.L.dtype).at[:n, :n].set(fn.L)
    return LogDet(L=L, n=n_to, max_select=fn.max_select)


def _pad_gcmi(fn: GCMI, n_to: int) -> GCMI:
    import jax.numpy as jnp

    qsum = jnp.zeros((n_to,), fn.qsum.dtype).at[: fn.n].set(fn.qsum)
    return GCMI(qsum=qsum, n=n_to)


def _pad_flqmi(fn: FLQMI, n_to: int) -> FLQMI:
    import jax.numpy as jnp

    nq, n = fn.sim_qv.shape
    # zero COLUMNS only, like FacilityLocation: the query-side row axis is a
    # sum-reduction axis and may never be padded (reduction-tree ulps).
    sim_qv = jnp.zeros((nq, n_to), fn.sim_qv.dtype).at[:, :n].set(fn.sim_qv)
    modular = jnp.zeros((n_to,), fn.modular.dtype).at[:n].set(fn.modular)
    return FLQMI(sim_qv=sim_qv, modular=modular, n=n_to)


def _pad_ground_cols(sim, n_to: int):
    import jax.numpy as jnp

    nv, n = sim.shape
    return jnp.zeros((nv, n_to), sim.dtype).at[:, :n].set(sim)


def _pad_flvmi(fn: FLVMI, n_to: int) -> FLVMI:
    return FLVMI(sim=_pad_ground_cols(fn.sim, n_to), qmax=fn.qmax, n=n_to)


def _pad_flcg(fn: FLCG, n_to: int) -> FLCG:
    return FLCG(sim=_pad_ground_cols(fn.sim, n_to), pmax=fn.pmax, n=n_to)


def _pad_flcmi(fn: FLCMI, n_to: int) -> FLCMI:
    return FLCMI(
        sim=_pad_ground_cols(fn.sim, n_to), qmax=fn.qmax, pmax=fn.pmax, n=n_to
    )


def _pad_source_cols(src, n_to: int):
    """Pad a similarity source's CANDIDATE (column) axis only — the row
    axis is a sum-reduction axis and is never padded (same reduction-tree
    argument as ``_pad_fl``)."""
    import dataclasses as _dc

    import jax.numpy as jnp

    if isinstance(src, FeatureSource):
        n = src.n_cols
        y = jnp.zeros((n_to, src.y.shape[1]), src.y.dtype).at[:n].set(src.y)
        yy = jnp.zeros((n_to,), src.yy.dtype).at[:n].set(src.yy)
        clab = src.col_labels
        if clab is not None:
            clab = jnp.full((n_to,), -1, jnp.int32).at[:n].set(clab)
        return _dc.replace(src, y=y, yy=yy, col_labels=clab, n_cols=n_to)
    if isinstance(src, KnnSource):
        # meta-only: the scatter target grows; indices/weights are untouched,
        # so real candidates' gains are bit-identical for free
        return _dc.replace(src, n_cols=n_to)
    if isinstance(src, DenseSource):
        n = src.n_cols
        sim = jnp.zeros((src.n_rows, n_to), src.sim.dtype).at[:, :n].set(src.sim)
        return _dc.replace(src, sim=sim, n_cols=n_to)
    raise NotImplementedError(
        f"no column padder for source type {type(src).__name__}"
    )


def _pad_source_square(src, n_to: int):
    """Pad a SQUARE ground-set source on both axes (Graph-Cut shape).

    Feature pad rows are zero-feature rows — their similarity to real
    points is generally nonzero (cosine midpoint, RBF at distance), but
    every read of those entries is blocked: pad candidates are
    valid-masked, pad columns carry selmask/total/diag 0, and ``col`` reads
    at pad rows only feed gains of pad candidates."""
    import dataclasses as _dc

    import jax.numpy as jnp

    if isinstance(src, FeatureSource):
        n = src.n_cols
        y = jnp.zeros((n_to, src.y.shape[1]), src.y.dtype).at[:n].set(src.y)
        yy = jnp.zeros((n_to,), src.yy.dtype).at[:n].set(src.yy)
        lab = src.col_labels
        if lab is not None:
            lab = jnp.full((n_to,), -1, jnp.int32).at[:n].set(lab)
        return _dc.replace(
            src, x=y, y=y, xx=yy, yy=yy, row_labels=lab, col_labels=lab,
            n_rows=n_to, n_cols=n_to,
        )
    if isinstance(src, KnnSource):
        n = src.n_rows
        indices = jnp.full((n_to, src.k), -1, jnp.int32).at[:n].set(src.indices)
        weights = jnp.zeros((n_to, src.k), src.weights.dtype).at[:n].set(src.weights)
        return _dc.replace(
            src, indices=indices, weights=weights, n_rows=n_to, n_cols=n_to
        )
    if isinstance(src, DenseSource):
        n = src.n_cols
        sim = jnp.zeros((n_to, n_to), src.sim.dtype).at[:n, :n].set(src.sim)
        return _dc.replace(src, sim=sim, n_rows=n_to, n_cols=n_to)
    raise NotImplementedError(
        f"no square padder for source type {type(src).__name__}"
    )


def _pad_flmf(fn: FacilityLocationMF, n_to: int) -> FacilityLocationMF:
    return FacilityLocationMF(
        src=_pad_source_cols(fn.src, n_to), n=n_to, use_kernel=fn.use_kernel
    )


def _pad_gcmf(fn: GraphCutMF, n_to: int) -> GraphCutMF:
    import jax.numpy as jnp

    n = fn.n
    total = jnp.zeros((n_to,), fn.total.dtype).at[:n].set(fn.total)
    diag = jnp.zeros((n_to,), fn.diag.dtype).at[:n].set(fn.diag)
    return GraphCutMF(
        src=_pad_source_square(fn.src, n_to),
        total=total,
        diag=diag,
        lam=fn.lam,
        n=n_to,
        use_kernel=fn.use_kernel,
    )


_PADDERS: dict[type, Callable] = {
    FacilityLocation: _pad_fl,
    GraphCut: _pad_gc,
    FeatureBased: _pad_fb,
    SetCover: _pad_sc,
    ProbabilisticSetCover: _pad_psc,
    DisparitySum: _pad_square_dist,
    DisparityMin: _pad_square_dist,
    LogDet: _pad_logdet,
    GCMI: _pad_gcmi,
    FLQMI: _pad_flqmi,
    FLVMI: _pad_flvmi,
    FLCG: _pad_flcg,
    FLCMI: _pad_flcmi,
    FacilityLocationMF: _pad_flmf,
    GraphCutMF: _pad_gcmf,
}


def register_padder(cls: type, padder: Callable) -> None:
    """Plug in ``padder(fn, n_to) -> fn_padded`` for a function family."""
    _PADDERS[cls] = padder


def resolve_padder(cls: type) -> Callable:
    """The padder serving ``cls`` (resolved along the MRO), or a
    ``NotImplementedError`` naming :func:`register_padder`.  The serving
    front door calls this at submit time so an unsupported family is
    rejected before it can poison a flush."""
    for klass in cls.__mro__:
        padder = _PADDERS.get(klass)
        if padder is not None:
            return padder
    raise NotImplementedError(
        f"{cls.__name__} has no registered padder, so it cannot be "
        "coalesced into served waves; plug one in via "
        "repro.launch.coalesce.register_padder (see docs/functions.md for "
        "the families served out of the box)"
    )


def pad_function(fn, n_to: int):
    """Zero-pad ``fn``'s candidate axis to ``n_to`` (identity if equal).

    The registry is consulted even when no padding is needed: a family
    without a padder must fail the same way at every ground-set size, not
    only when its n misses a power-of-two bucket.  This is also the
    "padder" fault-injection boundary (``launch/faults.py``) — it fires
    even at exact bucket sizes, for the same any-size consistency reason.
    Materialization happens at flush time, so a padder fault aborts a
    drain *before* any queue entry is removed (or, on the resilient drain,
    isolates just the failing group)."""
    padder = resolve_padder(type(fn))
    faults.check("padder", family=type(fn).__name__, n=fn.n, n_to=n_to)
    if fn.n == n_to:
        return fn
    if fn.n > n_to:
        raise ValueError(f"cannot pad n={fn.n} down to {n_to}")
    return padder(fn, n_to)


# ---------------------------------------------------------------------------
# Waves
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Wave:
    """A homogeneous, statically-shaped batch ready for a batched engine."""

    requests: list[SelectionRequest]  # the real requests, in batch order
    fns: list  # padded instances; len >= len(requests) (batch pads at tail)
    valid: np.ndarray  # (B, n_bucket) bool
    budgets: list[int]  # per-slot budgets; 0 for batch-pad slots
    max_budget: int  # static loop bound (pow2 bucket of the largest budget)
    optimizer: OptimizerSpec  # shared by the wave (hyperparameters included)
    stop_if_zero: bool
    stop_if_negative: bool
    n_bucket: int

    @property
    def batch_size(self) -> int:
        return len(self.fns)

    @property
    def n_padded_slots(self) -> int:
        return len(self.fns) - len(self.requests)

    @property
    def label(self) -> str:
        """Metrics label of the group that produced this wave — matches
        :func:`group_label` for every member request."""
        return (
            f"{type(self.requests[0].spec.fn).__name__}/n{self.n_bucket}"
            f"/{self.optimizer.name}"
        )

    def demux(self, results: Sequence) -> dict:
        """Map per-slot engine results back to {rid: result}, dropping the
        batch-pad slots.  ``results`` is whatever the engine returned, in
        slot order (GreedyResults or [(idx, gain), ...] lists)."""
        return {req.rid: results[i] for i, req in enumerate(self.requests)}


# -- group keys: wave identity, promoted to queue identity --------------------
#
# Requests sharing a group key can ride one engine dispatch, so the key is
# ALSO the right identity for the serving front door's pending queues
# (continuous batching: a late request joins the next wave of *its* group
# instead of waiting for a global flush).  The key must therefore be cheap
# enough to compute at submit time: the padded pytree layout is derived
# shape-only via ``jax.eval_shape`` (no FLOPs, no device buffers) and
# memoized per (treedef, leaf shapes/dtypes, n_bucket).

_LAYOUT_CACHE: dict = {}


def _padded_layout(fn, n_bucket: int) -> tuple:
    """(pytree structure, leaf shapes) of ``pad_function(fn, n_bucket)``,
    computed without materializing any padded array."""
    leaves, treedef = jax.tree.flatten(fn)
    cache_key = (
        treedef,
        tuple((tuple(leaf.shape), str(leaf.dtype)) for leaf in leaves),
        n_bucket,
    )
    layout = _LAYOUT_CACHE.get(cache_key)
    if layout is None:
        padded = jax.eval_shape(lambda f: pad_function(f, n_bucket), fn)
        layout = (
            jax.tree.structure(padded),
            tuple(tuple(leaf.shape) for leaf in jax.tree.leaves(padded)),
        )
        _LAYOUT_CACHE[cache_key] = layout
    return layout


def group_key(req: SelectionRequest, *, n_multiple: int = 1) -> tuple:
    """The (family, n-bucket) group identity of a request.

    Two requests with equal keys coalesce into the same wave: padded pytree
    structure + leaf shapes, the (hashable) OptimizerSpec — hyperparameters
    ride along without being enumerated — and the stop flags.  Budgets and
    deadlines deliberately do NOT key: waves mix budgets under one bucketed
    loop bound, and a deadline shapes flush *scheduling*, never wave
    membership.
    """
    fn = req.fn  # the spec's backend choice applied
    n_bucket = bucket_size(fn.n, n_multiple)
    structure, shapes = _padded_layout(fn, n_bucket)
    spec = req.spec
    return (
        structure,
        shapes,
        spec.optimizer,
        spec.stop_if_zero,
        spec.stop_if_negative,
    )


def group_label(req: SelectionRequest, *, n_multiple: int = 1) -> str:
    """Human-readable metrics label for the request's group:
    ``Family/n<bucket>/<Optimizer>`` (coarser than :func:`group_key` — leaf
    shapes beyond the n-bucket are folded away for readability)."""
    fn = req.spec.fn
    return (
        f"{type(fn).__name__}/n{bucket_size(fn.n, n_multiple)}"
        f"/{req.spec.optimizer.name}"
    )


def waves_for_group(
    requests: Sequence[SelectionRequest],
    *,
    max_wave: int = 64,
    n_multiple: int = 1,
    b_multiple: int = 1,
) -> list[Wave]:
    """Build dispatchable waves from requests sharing one :func:`group_key`
    (one queue's drain).  Padding is materialized HERE, at flush time —
    submit time only ever computes shapes."""
    members = []
    for req in requests:
        fn = req.fn
        members.append((req, pad_function(fn, bucket_size(fn.n, n_multiple))))
    head = requests[0].spec
    waves = []
    for lo in range(0, len(members), max_wave):
        chunk = members[lo : lo + max_wave]
        reqs = [r for r, _ in chunk]
        fns = [f for _, f in chunk]
        budgets = [r.budget for r in reqs]
        # batch pads: budget-0 copies of slot 0, dropped at demux
        b_total = -(-len(fns) // b_multiple) * b_multiple
        fns = fns + [fns[0]] * (b_total - len(fns))
        budgets = budgets + [0] * (b_total - len(reqs))
        n_bucket = fns[0].n
        valid = np.zeros((b_total, n_bucket), bool)
        for i in range(b_total):
            true_n = reqs[i].spec.fn.n if i < len(reqs) else reqs[0].spec.fn.n
            valid[i, :true_n] = True
        waves.append(
            Wave(
                requests=reqs,
                fns=fns,
                valid=valid,
                budgets=budgets,
                max_budget=next_pow2(max(budgets)) if max(budgets) else 1,
                optimizer=head.optimizer,
                stop_if_zero=head.stop_if_zero,
                stop_if_negative=head.stop_if_negative,
                n_bucket=n_bucket,
            )
        )
    return waves


def coalesce(
    requests: Sequence[SelectionRequest],
    *,
    max_wave: int = 64,
    n_multiple: int = 1,
    b_multiple: int = 1,
) -> list[Wave]:
    """Group requests into dispatchable waves.

    Args:
      requests: pending selection requests (any mix of families/sizes).
      max_wave: cap on real requests per wave.
      n_multiple: pad every n-bucket up to a multiple of this (the mesh
        data-axis size for sharded serving).
      b_multiple: pad every wave's batch up to a multiple of this (the mesh
        batch-axis size for sharded serving).

    Returns waves in first-arrival order of their earliest request.  The
    serving front door keeps per-group queues keyed by :func:`group_key`
    and drains them through :func:`waves_for_group` directly; this function
    is the one-shot composition of the two for flat request lists.
    """
    groups: dict[tuple, list[SelectionRequest]] = {}
    for req in requests:
        groups.setdefault(group_key(req, n_multiple=n_multiple), []).append(req)
    waves = []
    for members in groups.values():
        waves.extend(
            waves_for_group(
                members,
                max_wave=max_wave,
                n_multiple=n_multiple,
                b_multiple=b_multiple,
            )
        )
    return waves
