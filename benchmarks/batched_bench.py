"""Batched multi-query engine throughput (serving-shaped workload).

The 'heavy traffic' regime is many concurrent small/medium selection queries
— one greedy selection each.  Compares three ways of answering a wave of B
FacilityLocation queries:

  - sequential: a Python loop of single jitted ``naive_greedy`` calls
    (one compile shared across instances, B dispatches per wave)
  - batched (one-shot): ``solve([SelectionSpec(...), ...], mode="batched")``
    — spec + stack + one vmap-ed dispatch per call
  - engine (resident): :class:`BatchedEngine` stacked once at ingest, each
    wave is a single ``run`` dispatch (how a server actually runs)

Reported: wall time per wave, queries/sec, and speedup over the sequential
loop.  The batched paths must return identical per-instance selections,
asserted before timing.

A second table compares batched **NaiveGreedy vs LazyGreedy** (the
eval-sparse bucketed lazy engine): gain-evaluation counts AND wall clock,
on both flat and peaked gain distributions.  Flat gains are lazy greedy's
documented worst case (bound screens keep missing); peaked gains — the
regime Minoux '78 targets and real dedup/coreset kernels live in — is where
the [acceptance] >=2x wall-clock win over batched naive shows up on CPU.

``--json PATH`` dumps every row for trend tracking
(``benchmarks/BENCH_batched.json`` is the committed snapshot; diff two
snapshots with ``tools/bench_diff.py`` / ``make bench-diff``).

    PYTHONPATH=src python -m benchmarks.batched_bench
    PYTHONPATH=src python -m benchmarks.batched_bench --json benchmarks/BENCH_batched.json
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import numpy as np

from repro.core import (
    BatchedEngine,
    FacilityLocation,
    OptimizerSpec,
    SelectionSpec,
    create_kernel,
    lazy_greedy,
    naive_greedy,
    solve,
)


def make_instances(B=64, n=64, d=8, seed=0, peaked=False):
    """B FacilityLocation instances.  ``peaked=True`` scales each candidate
    column by a decaying factor, giving the head-heavy gain distribution
    lazy greedy targets (flat euclidean-kernel gains are its worst case)."""
    rng = np.random.default_rng(seed)
    fns = []
    for _ in range(B):
        x = rng.normal(size=(n, d)).astype(np.float32)
        S = np.asarray(create_kernel(x, metric="euclidean"))
        if peaked:
            scale = (0.99 ** np.arange(n))[rng.permutation(n)].astype(np.float32)
            S = S * scale[None, :]
        fns.append(FacilityLocation.from_kernel(S))
    return fns


# family -> (stopIfZeroGain, stopIfNegativeGain); the dispersion functions
# have zero empty-set gain, so their waves run with stopping disabled
FAMILIES = {
    "fl": (True, True),
    "gc": (True, True),
    "fb": (True, True),
    "sc": (True, True),
    "psc": (True, True),
    "dsum": (False, False),
    "dmin": (False, False),
    "flqmi": (True, True),
    "gcmi": (True, True),
    "logdet": (True, True),
}


def make_family_instances(family, B, n, seed=0):
    from repro.launch.serve import _random_function

    rng = np.random.default_rng(seed)
    return [_random_function(family, n, rng) for _ in range(B)]


def _time(fn, reps):
    fn()  # warm-up / compile
    best = float("inf")
    for _ in range(3):  # best-of-3 batches to shrug off scheduler noise
        t0 = time.perf_counter()
        for _ in range(reps):
            fn()
        best = min(best, (time.perf_counter() - t0) / reps)
    return best


def run(B: int = 64, n: int = 64, budget: int = 8, reps: int = 10):
    fns = make_instances(B, n)
    engine = BatchedEngine(fns)

    # correctness gate: batched selections identical to the sequential loop
    seq_res = [jax.block_until_ready(naive_greedy(f, budget)) for f in fns]
    for i, (a, b) in enumerate(zip(seq_res, engine.run(budget))):
        assert list(np.asarray(a.order)) == list(b.order), i

    t_seq = _time(
        lambda: [jax.block_until_ready(naive_greedy(f, budget)) for f in fns], reps
    )
    # one-shot: spec construction + engine build + dispatch, every call
    specs = [SelectionSpec(f, budget) for f in fns]
    t_oneshot = _time(lambda: solve(specs, mode="batched"), reps)
    t_engine = _time(lambda: engine.run(budget), reps)

    return {
        "B": B,
        "n": n,
        "budget": budget,
        "sequential_ms": t_seq * 1e3,
        "oneshot_ms": t_oneshot * 1e3,
        "engine_ms": t_engine * 1e3,
        "sequential_qps": B / t_seq,
        "engine_qps": B / t_engine,
        "oneshot_speedup": t_seq / t_oneshot,
        "engine_speedup": t_seq / t_engine,
    }


def run_family(family: str, B: int = 32, n: int = 64, budget: int = 8, reps: int = 5):
    """Engine-vs-sequential for one function family (the serving matrix)."""
    fns = make_family_instances(family, B, n)
    stop_zero, stop_neg = FAMILIES[family]
    engine = BatchedEngine(fns)

    def dispatch():
        return engine.run(
            budget, stop_if_zero=stop_zero, stop_if_negative=stop_neg
        )

    def sequential():
        return [
            jax.block_until_ready(naive_greedy(f, budget, stop_zero, stop_neg))
            for f in fns
        ]

    for a, b in zip(sequential(), dispatch()):  # correctness gate
        assert list(np.asarray(a.order)) == list(b.order), family

    t_seq = _time(sequential, reps)
    t_engine = _time(dispatch, reps)
    return {
        "family": family,
        "B": B,
        "n": n,
        "budget": budget,
        "sequential_ms": t_seq * 1e3,
        "engine_ms": t_engine * 1e3,
        "engine_qps": B / t_engine,
        "engine_speedup": t_seq / t_engine,
    }


def run_lazy(
    B: int,
    n: int,
    budget: int,
    screen_k: int = 32,
    peaked: bool = True,
    reps: int = 3,
):
    """Batched NaiveGreedy vs batched LazyGreedy on one resident engine:
    wall clock AND total gain-evaluation counts (the hardware-independent
    cost metric).  Correctness gate: the lazy wave must be bit-identical to
    a loop of sequential ``lazy_greedy`` calls, including n_evals."""
    fns = make_instances(B, n, peaked=peaked)
    engine = BatchedEngine(fns)

    lazy_spec = OptimizerSpec("LazyGreedy", screen_k=screen_k)

    def naive():
        return engine.run(budget)

    def lazy():
        return engine.run(budget, lazy_spec)

    naive_res, lazy_res = naive(), lazy()
    for i, (fn, r) in enumerate(zip(fns, lazy_res)):  # correctness gate
        seq = lazy_greedy(fn, budget, screen_k)
        assert list(np.asarray(seq.order)) == list(np.asarray(r.order)), i
        assert int(seq.n_evals) == int(r.n_evals), i

    naive_evals = sum(int(r.n_evals) for r in naive_res)
    lazy_evals = sum(int(r.n_evals) for r in lazy_res)
    t_naive = _time(naive, reps)
    t_lazy = _time(lazy, reps)
    return {
        "B": B,
        "n": n,
        "budget": budget,
        "screen_k": screen_k,
        "gains": "peaked" if peaked else "flat",
        "naive_ms": t_naive * 1e3,
        "lazy_ms": t_lazy * 1e3,
        "naive_evals": naive_evals,
        "lazy_evals": lazy_evals,
        "eval_ratio": naive_evals / lazy_evals,
        "lazy_qps": B / t_lazy,
        "lazy_speedup": t_naive / t_lazy,
    }


def main(json_path: str | None = None):
    rows = [
        run(B=8, n=64, budget=8),
        run(B=64, n=64, budget=8),
        run(B=256, n=64, budget=8),
        run(B=64, n=128, budget=8),
    ]
    print("\n# Batched multi-query engine vs sequential maximize loop")
    print(
        f"{'B':>4s} {'n':>5s} {'k':>3s} {'seq ms':>8s} {'1shot ms':>9s} "
        f"{'engine ms':>9s} {'seq q/s':>9s} {'engine q/s':>10s} "
        f"{'1shot x':>8s} {'engine x':>8s}"
    )
    for r in rows:
        print(
            f"{r['B']:4d} {r['n']:5d} {r['budget']:3d} {r['sequential_ms']:8.1f} "
            f"{r['oneshot_ms']:9.1f} {r['engine_ms']:9.1f} "
            f"{r['sequential_qps']:9.0f} {r['engine_qps']:10.0f} "
            f"{r['oneshot_speedup']:7.2f}x {r['engine_speedup']:7.2f}x"
        )
    best = max(r["engine_speedup"] for r in rows)
    print(f"\nbest engine speedup over sequential loop: {best:.2f}x")

    fam_rows = [run_family(f) for f in FAMILIES]
    print("\n# Family breadth: batched engine vs sequential loop per family")
    print(
        f"{'family':>8s} {'B':>4s} {'n':>5s} {'k':>3s} {'seq ms':>8s} "
        f"{'engine ms':>9s} {'engine q/s':>10s} {'engine x':>8s}"
    )
    for r in fam_rows:
        print(
            f"{r['family']:>8s} {r['B']:4d} {r['n']:5d} {r['budget']:3d} "
            f"{r['sequential_ms']:8.1f} {r['engine_ms']:9.1f} "
            f"{r['engine_qps']:10.0f} {r['engine_speedup']:7.2f}x"
        )

    lazy_rows = [
        run_lazy(8, 256, 16, peaked=False),
        run_lazy(8, 1024, 24, peaked=False),
        run_lazy(8, 1024, 24, peaked=True),
        run_lazy(16, 1024, 24, peaked=True),
        run_lazy(8, 2048, 32, peaked=True),
    ]
    print("\n# Batched NaiveGreedy vs LazyGreedy (bucketed lazy engine)")
    print(
        f"{'B':>4s} {'n':>5s} {'k':>3s} {'sk':>4s} {'gains':>7s} "
        f"{'naive ms':>9s} {'lazy ms':>8s} {'lazy x':>7s} "
        f"{'naive evals':>11s} {'lazy evals':>10s} {'eval x':>7s}"
    )
    for r in lazy_rows:
        print(
            f"{r['B']:4d} {r['n']:5d} {r['budget']:3d} {r['screen_k']:4d} "
            f"{r['gains']:>7s} {r['naive_ms']:9.1f} {r['lazy_ms']:8.1f} "
            f"{r['lazy_speedup']:6.2f}x {r['naive_evals']:11d} "
            f"{r['lazy_evals']:10d} {r['eval_ratio']:6.1f}x"
        )
    best_lazy = max(r["lazy_speedup"] for r in lazy_rows)
    print(f"\nbest lazy speedup over batched naive: {best_lazy:.2f}x")

    for r in rows:
        r["section"] = "engine_vs_sequential"
    for r in fam_rows:
        r["section"] = "family_breadth"
    for r in lazy_rows:
        r["section"] = "naive_vs_lazy"
    all_rows = rows + fam_rows + lazy_rows
    if json_path:
        snapshot = {
            "bench": "batched_bench",
            "host": platform.machine(),
            "backend": jax.default_backend(),
            "devices": len(jax.devices()),
            "jax": jax.__version__,
            "rows": all_rows,
        }
        with open(json_path, "w") as f:
            json.dump(snapshot, f, indent=1)
        print(f"wrote {len(all_rows)} rows to {json_path}")
    return all_rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="dump rows to this path")
    main(json_path=ap.parse_args().json)
