"""Batched multi-query engine throughput (serving-shaped workload).

The 'heavy traffic' regime is many concurrent small/medium selection queries
— one greedy selection each.  Compares three ways of answering a wave of B
FacilityLocation queries:

  - sequential: a Python loop of single jitted ``naive_greedy`` calls
    (one compile shared across instances, B dispatches per wave)
  - batched (one-shot): ``batched_maximize`` — stack + one vmap-ed dispatch
  - engine (resident): :class:`BatchedEngine` stacked once at ingest, each
    wave is a single dispatch (how a server actually runs)

Reported: wall time per wave, queries/sec, and speedup over the sequential
loop.  The batched paths must return identical per-instance selections,
asserted before timing.

    PYTHONPATH=src python -m benchmarks.batched_bench
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import (
    BatchedEngine,
    FacilityLocation,
    batched_maximize,
    create_kernel,
    naive_greedy,
)


def make_instances(B=64, n=64, d=8, seed=0):
    rng = np.random.default_rng(seed)
    fns = []
    for _ in range(B):
        x = rng.normal(size=(n, d)).astype(np.float32)
        S = np.asarray(create_kernel(x, metric="euclidean"))
        fns.append(FacilityLocation.from_kernel(S))
    return fns


# family -> (stopIfZeroGain, stopIfNegativeGain); the dispersion functions
# have zero empty-set gain, so their waves run with stopping disabled
FAMILIES = {
    "fl": (True, True),
    "gc": (True, True),
    "fb": (True, True),
    "sc": (True, True),
    "psc": (True, True),
    "dsum": (False, False),
    "dmin": (False, False),
    "flqmi": (True, True),
    "gcmi": (True, True),
    "logdet": (True, True),
}


def make_family_instances(family, B, n, seed=0):
    from repro.launch.serve import _random_function

    rng = np.random.default_rng(seed)
    return [_random_function(family, n, rng) for _ in range(B)]


def _time(fn, reps):
    fn()  # warm-up / compile
    best = float("inf")
    for _ in range(3):  # best-of-3 batches to shrug off scheduler noise
        t0 = time.perf_counter()
        for _ in range(reps):
            fn()
        best = min(best, (time.perf_counter() - t0) / reps)
    return best


def run(B: int = 64, n: int = 64, budget: int = 8, reps: int = 10):
    fns = make_instances(B, n)
    engine = BatchedEngine(fns)

    # correctness gate: batched selections identical to the sequential loop
    seq_res = [jax.block_until_ready(naive_greedy(f, budget)) for f in fns]
    for i, (a, b) in enumerate(
        zip(seq_res, engine.maximize(budget, return_result=True))
    ):
        assert list(np.asarray(a.order)) == list(b.order), i

    t_seq = _time(
        lambda: [jax.block_until_ready(naive_greedy(f, budget)) for f in fns], reps
    )
    t_oneshot = _time(
        lambda: batched_maximize(fns, budget, return_result=True), reps
    )
    t_engine = _time(lambda: engine.maximize(budget, return_result=True), reps)

    return {
        "B": B,
        "n": n,
        "budget": budget,
        "sequential_ms": t_seq * 1e3,
        "oneshot_ms": t_oneshot * 1e3,
        "engine_ms": t_engine * 1e3,
        "sequential_qps": B / t_seq,
        "engine_qps": B / t_engine,
        "oneshot_speedup": t_seq / t_oneshot,
        "engine_speedup": t_seq / t_engine,
    }


def run_family(family: str, B: int = 32, n: int = 64, budget: int = 8, reps: int = 5):
    """Engine-vs-sequential for one function family (the serving matrix)."""
    fns = make_family_instances(family, B, n)
    stop_zero, stop_neg = FAMILIES[family]
    engine = BatchedEngine(fns)

    def dispatch():
        return engine.maximize(
            budget,
            return_result=True,
            stopIfZeroGain=stop_zero,
            stopIfNegativeGain=stop_neg,
        )

    def sequential():
        return [
            jax.block_until_ready(naive_greedy(f, budget, stop_zero, stop_neg))
            for f in fns
        ]

    for a, b in zip(sequential(), dispatch()):  # correctness gate
        assert list(np.asarray(a.order)) == list(b.order), family

    t_seq = _time(sequential, reps)
    t_engine = _time(dispatch, reps)
    return {
        "family": family,
        "B": B,
        "n": n,
        "budget": budget,
        "sequential_ms": t_seq * 1e3,
        "engine_ms": t_engine * 1e3,
        "engine_qps": B / t_engine,
        "engine_speedup": t_seq / t_engine,
    }


def main():
    rows = [
        run(B=8, n=64, budget=8),
        run(B=64, n=64, budget=8),
        run(B=256, n=64, budget=8),
        run(B=64, n=128, budget=8),
    ]
    print("\n# Batched multi-query engine vs sequential maximize loop")
    print(
        f"{'B':>4s} {'n':>5s} {'k':>3s} {'seq ms':>8s} {'1shot ms':>9s} "
        f"{'engine ms':>9s} {'seq q/s':>9s} {'engine q/s':>10s} "
        f"{'1shot x':>8s} {'engine x':>8s}"
    )
    for r in rows:
        print(
            f"{r['B']:4d} {r['n']:5d} {r['budget']:3d} {r['sequential_ms']:8.1f} "
            f"{r['oneshot_ms']:9.1f} {r['engine_ms']:9.1f} "
            f"{r['sequential_qps']:9.0f} {r['engine_qps']:10.0f} "
            f"{r['oneshot_speedup']:7.2f}x {r['engine_speedup']:7.2f}x"
        )
    best = max(r["engine_speedup"] for r in rows)
    print(f"\nbest engine speedup over sequential loop: {best:.2f}x")

    fam_rows = [run_family(f) for f in FAMILIES]
    print("\n# Family breadth: batched engine vs sequential loop per family")
    print(
        f"{'family':>8s} {'B':>4s} {'n':>5s} {'k':>3s} {'seq ms':>8s} "
        f"{'engine ms':>9s} {'engine q/s':>10s} {'engine x':>8s}"
    )
    for r in fam_rows:
        print(
            f"{r['family']:>8s} {r['B']:4d} {r['n']:5d} {r['budget']:3d} "
            f"{r['sequential_ms']:8.1f} {r['engine_ms']:9.1f} "
            f"{r['engine_qps']:10.0f} {r['engine_speedup']:7.2f}x"
        )
    return rows + fam_rows


if __name__ == "__main__":
    main()
