"""Paper Figs. 7/8/10 reproduction (quantified): FLQMI eta-sweep and GCMI
retrieval behaviour on a clustered 2D ground set with a 2-cluster query set.

Metrics:
  query-relevance : mean max-similarity of each selected point to the query
  query-coverage  : #queries whose nearest selected point is within eps
  diversity       : mean pairwise distance among selected points

Claims: FLQMI at eta=0 picks ~one point per query then saturates; higher eta
increases query-relevance and *reduces* coverage/diversity; GCMI behaves as
a pure retrieval function (top-similarity picks, lowest diversity).
"""
from __future__ import annotations

import numpy as np

from repro.core import FLQMI, GCMI, create_kernel, naive_greedy

ETAS = [0.0, 0.4, 1.0, 3.0, 10.0]


def make_dataset(seed=0):
    rng = np.random.default_rng(seed)
    centers = np.array(
        [[0, 0], [10, 0], [0, 10], [10, 10], [5, 5]], np.float32
    )
    ground = np.concatenate(
        [
            c + rng.normal(scale=0.8, size=(9, 2)).astype(np.float32)
            for c in centers
        ]
    )
    # queries near two of the clusters
    queries = np.concatenate(
        [
            centers[1] + rng.normal(scale=0.5, size=(2, 2)).astype(np.float32),
            centers[2] + rng.normal(scale=0.5, size=(2, 2)).astype(np.float32),
        ]
    )
    return ground, queries


def _metrics(ground, queries, sel):
    pts = ground[sel]
    dq = np.sqrt(((queries[:, None] - pts[None, :]) ** 2).sum(-1))
    coverage = int((dq.min(axis=1) < 2.0).sum())
    relevance = float((1.0 / (1.0 + dq.min(axis=0))).mean())
    dp = np.sqrt(((pts[:, None] - pts[None, :]) ** 2).sum(-1))
    diversity = float(dp[~np.eye(len(sel), dtype=bool)].mean()) if len(sel) > 1 else 0.0
    return coverage, relevance, diversity


def run(budget=8):
    ground, queries = make_dataset()
    S_qv = np.asarray(create_kernel(queries, ground, metric="euclidean"))
    S_vq = np.asarray(create_kernel(ground, queries, metric="euclidean"))
    rows = []
    for eta in ETAS:
        fn = FLQMI.build(S_qv, eta=eta)
        res = naive_greedy(fn, budget, False, False)
        sel = [i for i, _ in res.as_list()]
        cov, rel, div = _metrics(ground, queries, sel)
        gains = [g for _, g in res.as_list()]
        rows.append(
            {
                "fn": f"FLQMI eta={eta}",
                "coverage": cov,
                "relevance": rel,
                "diversity": div,
                "gain_drop_after_nq": gains[len(queries)] / (gains[0] + 1e-9),
            }
        )
    gc = naive_greedy(GCMI.build(S_vq, lam=0.5), budget, False, False)
    sel = [i for i, _ in gc.as_list()]
    cov, rel, div = _metrics(ground, queries, sel)
    # pure-retrieval claim: GCMI's greedy == top-k by summed query similarity
    topk = list(np.argsort(-S_vq.sum(axis=1))[:budget])
    assert sel == [int(i) for i in topk], "GCMI must rank purely by query similarity"
    rows.append(
        {"fn": "GCMI", "coverage": cov, "relevance": rel, "diversity": div,
         "gain_drop_after_nq": float("nan")}
    )
    return rows


def main():
    rows = run()
    print("\n# Figs. 7/8/10 reproduction — FLQMI eta-sweep + GCMI retrieval")
    print(f"{'function':16s} {'coverage':>9s} {'relevance':>10s} {'diversity':>10s} {'gain@|Q|/gain@0':>16s}")
    for r in rows:
        print(
            f"{r['fn']:16s} {r['coverage']:9d} {r['relevance']:10.3f} "
            f"{r['diversity']:10.3f} {r['gain_drop_after_nq']:16.3f}"
        )
    # claims
    eta0 = rows[0]
    assert eta0["gain_drop_after_nq"] < 0.3, "FLQMI eta=0 must saturate after |Q| picks"
    assert rows[-2]["relevance"] >= rows[0]["relevance"] - 1e-6, "higher eta -> more query-relevant"
    assert rows[-2]["diversity"] <= rows[0]["diversity"] + 1e-6, "higher eta -> less diverse"
    print("claims: FLQMI saturation / eta trade-off / GCMI pure-retrieval — CONFIRMED")
    return rows


if __name__ == "__main__":
    main()
