"""Paper Table 5 reproduction: FacilityLocation selection wall-time vs
ground-set size on 1024-dimensional random points (kernel creation + greedy
maximization, budget 10).

Also reports the kernel-creation share — the paper's engine is dominated by
the O(n^2 d) kernel at scale, which is exactly what the Pallas MXU kernel
targets (DESIGN §2)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import FacilityLocation, create_kernel, lazy_greedy

SIZES = [50, 100, 200, 500, 1000, 2000, 5000]


def run(sizes=SIZES, d=1024, budget=10, use_pallas=False):
    rng = np.random.default_rng(0)
    rows = []
    for n in sizes:
        pts = rng.normal(size=(n, d)).astype(np.float32)

        def full():
            S = create_kernel(pts, metric="euclidean", use_pallas=use_pallas)
            fn = FacilityLocation.from_kernel(S)
            return lazy_greedy(fn, budget)

        jax.block_until_ready(full())  # compile
        t0 = time.perf_counter()
        res = jax.block_until_ready(full())
        total = time.perf_counter() - t0

        t0 = time.perf_counter()
        S = jax.block_until_ready(
            create_kernel(pts, metric="euclidean", use_pallas=use_pallas)
        )
        kernel_t = time.perf_counter() - t0
        rows.append(
            {
                "n": n,
                "total_s": total,
                "kernel_s": kernel_t,
                "kernel_share": kernel_t / max(total, 1e-9),
                "objective": float(res.value),
            }
        )
    return rows


def main():
    rows = run()
    print("\n# Table 5 reproduction — FL selection timing vs n (d=1024)")
    print(f"{'n':>6s} {'total_s':>9s} {'kernel_s':>9s} {'kernel%':>8s}")
    for r in rows:
        print(
            f"{r['n']:6d} {r['total_s']:9.4f} {r['kernel_s']:9.4f} "
            f"{100 * r['kernel_share']:7.1f}%"
        )
    # scaling claim: ~quadratic growth at large n (paper Table 5 shape)
    big = [r for r in rows if r["n"] >= 1000]
    if len(big) >= 2:
        r1, r2 = big[0], big[-1]
        exponent = np.log(r2["total_s"] / r1["total_s"]) / np.log(
            r2["n"] / r1["n"]
        )
        print(f"empirical scaling exponent (n>=1000): {exponent:.2f} (paper ~2)")
    return rows


if __name__ == "__main__":
    main()
