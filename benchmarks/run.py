"""Benchmark driver — one benchmark per paper table/figure, plus the
roofline table derived from the dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.run

Emits ``name,us_per_call,derived`` CSV lines at the end for machine
consumption.
"""
from __future__ import annotations

import time


def main() -> None:
    csv = []

    from benchmarks import (
        batched_bench,
        mi_bench,
        modeling_bench,
        optimizers_bench,
        timing_bench,
    )

    t0 = time.perf_counter()
    opt_rows = optimizers_bench.main()
    csv.append(("optimizers_bench(table2)", (time.perf_counter() - t0) * 1e6,
                f"best_obj={max(r['objective'] for r in opt_rows):.2f}"))
    for r in opt_rows:
        csv.append(
            (f"opt/{r['optimizer'].split('(')[0]}", r["ms_per_run"] * 1e3,
             f"evals={r['gain_evals']}")
        )

    t0 = time.perf_counter()
    bat_rows = batched_bench.main()
    eng_rows = [r for r in bat_rows if "engine_speedup" in r]
    csv.append(("batched_bench(engine)", (time.perf_counter() - t0) * 1e6,
                f"best_speedup={max(r['engine_speedup'] for r in eng_rows):.2f}x"))
    for r in eng_rows:
        csv.append(
            (f"batched/B={r['B']},n={r['n']}", r["engine_ms"] * 1e3,
             f"qps={r['engine_qps']:.0f};speedup={r['engine_speedup']:.2f}x")
        )
    for r in bat_rows:
        if r.get("section") == "naive_vs_lazy":
            csv.append(
                (f"batched_lazy/B={r['B']},n={r['n']},{r['gains']}",
                 r["lazy_ms"] * 1e3,
                 f"speedup={r['lazy_speedup']:.2f}x;evals={r['lazy_evals']}")
            )

    t0 = time.perf_counter()
    tim_rows = timing_bench.main()
    csv.append(("timing_bench(table5)", (time.perf_counter() - t0) * 1e6,
                f"n_max={tim_rows[-1]['n']}"))
    for r in tim_rows:
        csv.append((f"timing/n={r['n']}", r["total_s"] * 1e6,
                    f"kernel_share={r['kernel_share']:.2f}"))

    t0 = time.perf_counter()
    modeling_bench.main()
    csv.append(("modeling_bench(fig5)", (time.perf_counter() - t0) * 1e6, "claims_ok"))

    t0 = time.perf_counter()
    mi_bench.main()
    csv.append(("mi_bench(fig7-8-10)", (time.perf_counter() - t0) * 1e6, "claims_ok"))

    t0 = time.perf_counter()
    from benchmarks import roofline

    roof_rows = roofline.main()
    csv.append(("roofline(dry-run)", (time.perf_counter() - t0) * 1e6,
                f"cells={len(roof_rows)}"))
    for r in roof_rows:
        csv.append(
            (f"roofline/{r['arch']}/{r['shape']}",
             max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
             f"dominant={r['dominant']};roofline={r['roofline_fraction']:.3f}")
        )

    print("\nname,us_per_call,derived")
    for name, us, derived in csv:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
