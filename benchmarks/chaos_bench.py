"""Chaos bench: what resilience costs, and that its accounting is exact.

Every scenario arms a seeded :class:`repro.launch.faults.FaultPlan` against
a **synchronous** server (no timer races), so the resilience counters —
``retries_total``, ``fallbacks_total``, ``quarantined_total``,
``errors_total`` (the server's ``flush_errors``), ``served_total`` — are
deterministic by construction and ``tools/bench_diff.py`` compares them
EXACTLY (the ``*_total`` rule): drift means the retry/fallback machinery
changed, not the machine.  Wall-clock columns stay loose:

  - ``recovery_ms``  — time from first (faulted) dispatch to every request
    resolved, or for ``crash_restore`` the full journal-replay time; diffed
    lower-is-better at the smokes' 50% threshold.
  - ``degraded_qps`` — throughput on the breaker-degraded path (fallback
    scenario only); diffed higher-is-better.

Scenarios:

  - ``retry``         — a transient dispatch fault; the wave retries and
    every answer is bit-identical to sequential ``solve()`` (asserted).
  - ``quarantine``    — one poison rid; co-travellers are isolated into
    singleton waves and served, the poison fails typed after max_attempts.
  - ``fallback``      — a persistently failing Pallas kernel trips the
    (family, kernel) breaker; work reroutes to XLA, degraded-but-exact.
  - ``crash_restore`` — journaled session deltas replayed onto a fresh
    server, restored state bit-identical (asserted).

    PYTHONPATH=src python -m benchmarks.chaos_bench          # full sweep
    PYTHONPATH=src python -m benchmarks.chaos_bench --quick  # smoke cells
    PYTHONPATH=src python -m benchmarks.chaos_bench --json benchmarks/BENCH_resilience.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import platform
import shutil
import tempfile
import time

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    FacilityLocation,
    FeatureBased,
    SelectionSpec,
    create_kernel,
    solve,
)
from repro.launch import faults  # noqa: E402
from repro.launch.faults import FaultPlan, FaultSpec  # noqa: E402
from repro.launch.resilience import BreakerBoard, RetryPolicy  # noqa: E402
from repro.launch.serve import SelectionServer  # noqa: E402
from repro.launch.sessions import SessionJournal, restore_sessions  # noqa: E402

D = 8
BUDGET = 4
POLICY = RetryPolicy(max_attempts=3, backoff_s=0.0, jitter=0.0)


def _fl_spec(rng, n, use_kernel=False):
    x = rng.normal(size=(n, D)).astype(np.float32)
    S = np.asarray(create_kernel(x, metric="euclidean"))
    return SelectionSpec(FacilityLocation.from_kernel(S, use_kernel=use_kernel),
                         BUDGET)


def _warm(spec):
    """Pay jit compile outside the timed window."""
    jax.block_until_ready(solve(spec).gains)


def _counters(server, served):
    c = server.metrics.counters
    return {
        "retries_total": int(c["retries_total"]),
        "fallbacks_total": int(c["fallbacks_total"]),
        "quarantined_total": int(c["quarantined_total"]),
        "errors_total": int(c["flush_errors"]),
        "served_total": int(served),
    }


def bench_retry(n, requests):
    rng = np.random.default_rng(0)
    specs = [_fl_spec(rng, n) for _ in range(requests)]
    expected = [solve(s).as_list() for s in specs]
    _warm(specs[0])
    server = SelectionServer(retry_policy=POLICY)
    rids = [server.submit_spec(s) for s in specs]
    plan = FaultPlan([FaultSpec(site="dispatch", times=1)])
    t0 = time.perf_counter()
    with faults.inject(plan):
        out = server.flush()
    dt = time.perf_counter() - t0
    assert sorted(out) == sorted(rids) and not server.take_failures()
    for rid, want in zip(rids, expected):
        assert out[rid].selection == want  # recovery is bit-identical
    return {
        "scenario": "retry", "n": n, "requests": requests,
        "recovery_ms": round(dt * 1e3, 2),
        **_counters(server, len(out)),
    }


def bench_quarantine(n, requests):
    rng = np.random.default_rng(1)
    specs = [_fl_spec(rng, n) for _ in range(requests)]
    _warm(specs[0])
    server = SelectionServer(retry_policy=POLICY)
    rids = [server.submit_spec(s) for s in specs]
    plan = FaultPlan([FaultSpec(site="dispatch", rid=rids[0], times=None)])
    t0 = time.perf_counter()
    with faults.inject(plan):
        out = server.flush()
    dt = time.perf_counter() - t0
    fails = server.take_failures()
    assert set(fails) == {rids[0]}  # the poison fails typed, alone
    assert sorted(out) == sorted(rids[1:])  # co-travellers all served
    return {
        "scenario": "quarantine", "n": n, "requests": requests,
        "recovery_ms": round(dt * 1e3, 2),
        **_counters(server, len(out)),
    }


def bench_fallback(n, requests):
    rng = np.random.default_rng(2)
    specs = [_fl_spec(rng, n, use_kernel=True) for _ in range(requests)]
    # warm the XLA path: that's what the tripped breaker dispatches onto
    _warm(SelectionSpec(dataclasses.replace(specs[0].fn, use_kernel=False),
                        BUDGET))
    server = SelectionServer(retry_policy=POLICY,
                             breakers=BreakerBoard(threshold=1))
    rids = [server.submit_spec(s) for s in specs]
    plan = FaultPlan([FaultSpec(site="kernel", backend="pallas-*", times=None)])
    t0 = time.perf_counter()
    with faults.inject(plan):
        out = server.flush()
    dt = time.perf_counter() - t0
    assert sorted(out) == sorted(rids) and not server.take_failures()
    assert all(out[r].degraded == "xla" for r in rids)  # breaker rerouted
    return {
        "scenario": "fallback", "n": n, "requests": requests,
        "recovery_ms": round(dt * 1e3, 2),
        "degraded_qps": round(requests / dt, 2),
        **_counters(server, len(out)),
    }


def bench_crash_restore(n, deltas):
    rng = np.random.default_rng(3)
    f0 = rng.uniform(0.0, 1.0, size=(n, D)).astype(np.float32)
    spec = SelectionSpec(FeatureBased.from_features(f0, concave="sqrt"), BUDGET)
    _warm(spec)
    root = tempfile.mkdtemp(prefix="chaos_journal_")
    try:
        journal = SessionJournal(root)
        server = SelectionServer()
        session = server.open_session(spec, sid="bench", journal=journal)
        for _ in range(deltas):
            session.extend(
                features=rng.uniform(0.0, 1.0, size=(4, D)).astype(np.float32)
            )
        want = session.last_update.selection
        server2 = SelectionServer()  # the "crash": a fresh server
        t0 = time.perf_counter()
        restored = restore_sessions(server2, journal, {"bench": spec})
        dt = time.perf_counter() - t0
        r = restored["bench"]
        assert r._seq == deltas and r.last_update.selection == want
        return {
            "scenario": "crash_restore", "n": n, "requests": deltas,
            "recovery_ms": round(dt * 1e3, 2),
            **_counters(server2, deltas),  # replayed deltas, all served
        }
    finally:
        shutil.rmtree(root, ignore_errors=True)


RUNNERS = {
    "retry": bench_retry,
    "quarantine": bench_quarantine,
    "fallback": bench_fallback,
    "crash_restore": bench_crash_restore,
}

# full sweep: (scenario, n, requests-or-deltas).  The quick cells are a
# strict subset so `make chaos-smoke`'s diff of a --quick run compares real
# committed rows.
QUICK_CELLS = [
    ("retry", 32, 4),
    ("quarantine", 32, 4),
    ("fallback", 32, 4),
    ("crash_restore", 16, 3),
]
FULL_CELLS = QUICK_CELLS + [
    ("retry", 64, 8),
    ("quarantine", 64, 8),
]


def _print_rows(title, rows):
    print(f"\n# {title}")
    print(f"{'scenario':>14s} {'n':>5s} {'reqs':>5s} {'recov ms':>9s} "
          f"{'retries':>8s} {'fallbk':>7s} {'quar':>5s} {'errs':>5s} "
          f"{'served':>7s}")
    for r in rows:
        print(f"{r['scenario']:>14s} {r['n']:5d} {r['requests']:5d} "
              f"{r['recovery_ms']:9.1f} {r['retries_total']:8d} "
              f"{r['fallbacks_total']:7d} {r['quarantined_total']:5d} "
              f"{r['errors_total']:5d} {r['served_total']:7d}")


def main(quick: bool = False, json_path: str | None = None):
    cells = QUICK_CELLS if quick else FULL_CELLS
    rows = [RUNNERS[scenario](n, requests) for scenario, n, requests in cells]
    _print_rows("Chaos: retry / quarantine / fallback / crash-restore", rows)
    if json_path:
        snapshot = {
            "bench": "chaos_bench",
            "host": platform.machine(),
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "rows": rows,
        }
        with open(json_path, "w") as f:
            json.dump(snapshot, f, indent=1)
        print(f"wrote {len(rows)} rows to {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="smoke sweep")
    ap.add_argument("--json", default=None, help="dump rows to this path")
    a = ap.parse_args()
    main(quick=a.quick, json_path=a.json)
