"""Offline greedy vs streaming selection: wall clock + oracle eval counts.

The streaming optimizers' claim (docs/streaming.md, tests/test_streaming.py)
is that SieveStreaming reads each arrival ONCE — a single pass over the
stream, where offline greedy re-sweeps the whole ground set for every one
of its k picks.  This bench records both sides per cell:

  - ``select_ms`` — wall time for one full ``solve()`` (best of 3 after a
    compile warm-up); noisy on shared boxes, diffed at a loose threshold by
    ``make stream-smoke``.
  - ``n_evals``   — the engine's own oracle-call counter, exact and
    machine-independent (``tools/bench_diff.py`` compares it exactly and
    reports drift as a NOTE: a change means the algorithm changed, not the
    machine).  Sieve's count is independent of the ladder size L by design —
    all rungs share one batched gain sweep per arrival.

Families: ``fb`` is the matrix-free FeatureBased objective (gains stream
through the GainBackend, no n² kernel); ``fl`` is dense FacilityLocation
over a materialized RBF kernel.  The offline baselines are NaiveGreedy
(full re-sweep per pick) and LazyGreedy (priority-queue screening); the
streaming side is SieveStreaming and ThresholdGreedy.  ``--quick`` runs a
strict subset of the full sweep so ``make stream-smoke`` diffs real rows
against the committed ``benchmarks/BENCH_streaming.json``.

    PYTHONPATH=src python -m benchmarks.stream_bench          # full sweep
    PYTHONPATH=src python -m benchmarks.stream_bench --quick  # smoke cells
    PYTHONPATH=src python -m benchmarks.stream_bench --json benchmarks/BENCH_streaming.json
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    FacilityLocation,
    FeatureBased,
    SelectionSpec,
    create_kernel,
    solve,
)

D = 16
BUDGET = 8


def _build(family, n):
    rng = np.random.default_rng(0)
    if family == "fb":
        feats = rng.uniform(0.0, 1.0, size=(n, D)).astype(np.float32)
        return FeatureBased.from_features(feats)
    x = rng.standard_normal((n, D)).astype(np.float32)
    return FacilityLocation.from_kernel(np.asarray(create_kernel(x, metric="rbf")))


def _time(fn):
    fn()  # warm-up / compile
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run_cell(family, optimizer, n):
    fn = _build(family, n)
    spec = SelectionSpec(fn, BUDGET, optimizer)

    def run():
        return solve(spec)

    res = run()
    jax.block_until_ready(res.gains)
    t = _time(lambda: jax.block_until_ready(run().gains))
    return {
        "family": family,
        "optimizer": optimizer,
        "n": n,
        "budget": BUDGET,
        "select_ms": round(t * 1e3, 2),
        "n_evals": int(res.n_evals),
    }


# full sweep: (family, optimizer, n).  The quick cells are a strict subset
# so `make stream-smoke`'s diff of a --quick run compares real committed rows.
QUICK_CELLS = [
    ("fb", "NaiveGreedy", 1024),
    ("fb", "SieveStreaming", 1024),
    ("fl", "SieveStreaming", 512),
]
FULL_CELLS = QUICK_CELLS + [
    ("fb", "LazyGreedy", 1024),
    ("fb", "ThresholdGreedy", 1024),
    ("fb", "NaiveGreedy", 4096),
    ("fb", "SieveStreaming", 4096),
    ("fl", "NaiveGreedy", 512),
    ("fl", "LazyGreedy", 512),
    ("fl", "ThresholdGreedy", 512),
]


def _print_rows(title, rows):
    print(f"\n# {title}")
    print(f"{'family':>6s} {'optimizer':>16s} {'n':>6s} {'k':>3s} "
          f"{'select ms':>10s} {'evals':>9s}")
    for r in rows:
        print(f"{r['family']:>6s} {r['optimizer']:>16s} {r['n']:6d} "
              f"{r['budget']:3d} {r['select_ms']:10.1f} {r['n_evals']:9d}")


def main(quick: bool = False, json_path: str | None = None):
    cells = QUICK_CELLS if quick else FULL_CELLS
    rows = [run_cell(family, optimizer, n) for family, optimizer, n in cells]
    _print_rows("Offline greedy vs streaming selection: wall clock + evals",
                rows)
    if json_path:
        snapshot = {
            "bench": "stream_bench",
            "host": platform.machine(),
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "rows": rows,
        }
        with open(json_path, "w") as f:
            json.dump(snapshot, f, indent=1)
        print(f"wrote {len(rows)} rows to {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="smoke sweep")
    ap.add_argument("--json", default=None, help="dump rows to this path")
    a = ap.parse_args()
    main(quick=a.quick, json_path=a.json)
