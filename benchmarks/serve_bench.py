"""Distributed batched serving throughput: wave size x mesh shape x family.

Spawns 8 host-platform devices (XLA_FLAGS must be set before the first jax
import, so this module is its own entry point) and measures steady-state
wave throughput of the serving stack across:

  - wave sizes B (requests coalesced per dispatch),
  - mesh shapes (batch x data): how the wave is laid out over devices —
    1x1 is the single-device vmap engine; Bx1 shards only the batch axis;
    1xD shards only each instance's ground set; intermediate shapes do both,
  - function families: the full serving matrix (FL, GraphCut, FeatureBased,
    SetCover, ProbabilisticSetCover, Disparity*, FLQMI, GCMI, LogDet).

Reported per cell: wall time per wave and queries/sec (best of 3 after a
compile warm-up).  Selections are asserted bit-identical to the sequential
loop before timing.  A final "serving front door" row reports the
structured metrics (queue-time percentiles plus DETERMINISTIC rejection /
deadline-miss counts, which ``tools/bench_diff.py`` compares exactly).
``--json PATH`` dumps the rows for trend tracking —
``benchmarks/BENCH_serving.json`` is the committed snapshot, and
``make serve-smoke`` diffs a ``--quick`` run against it.

    PYTHONPATH=src python -m benchmarks.serve_bench          # full sweep
    PYTHONPATH=src python -m benchmarks.serve_bench --quick  # smoke cells
    PYTHONPATH=src python -m benchmarks.serve_bench --json benchmarks/BENCH_serving.json
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402  (after the device-count env var)
import numpy as np  # noqa: E402

from repro.core import naive_greedy  # noqa: E402
from repro.core.optimizers.batched import BatchedEngine  # noqa: E402
from repro.launch.serve import _random_function  # noqa: E402

# families x stopping flags: the dispersion functions have zero empty-set
# gain, so their waves run with stopping disabled (see docs/functions.md)
FAMILIES = {
    "fl": (True, True),
    "gc": (True, True),
    "fb": (True, True),
    "sc": (True, True),
    "psc": (True, True),
    "flqmi": (True, True),
    "gcmi": (True, True),
    "logdet": (True, True),
    "dsum": (False, False),
    "dmin": (False, False),
}


def make_instances(B, n, family="fl", seed=0):
    rng = np.random.default_rng(seed)
    return [_random_function(family, n, rng) for _ in range(B)]


def _time(fn, reps=5):
    fn()  # warm-up / compile
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(reps):
            fn()
        best = min(best, (time.perf_counter() - t0) / reps)
    return best


def run_cell(B, n, budget, mesh_shape, family="fl"):
    """One (wave size, mesh shape, family) cell; returns the timing row."""
    fns = make_instances(B, n, family)
    stop_zero, stop_neg = FAMILIES[family]
    if mesh_shape == (1, 1):
        engine = BatchedEngine(fns)  # single-device vmap engine
    else:
        mesh = jax.make_mesh(mesh_shape, ("batch", "data"))
        engine = BatchedEngine(fns, mesh=mesh)

    def dispatch():
        return engine.run(
            budget, stop_if_zero=stop_zero, stop_if_negative=stop_neg
        )

    # correctness gate before timing: bit-identical to the sequential loop
    for fn, r in zip(fns, dispatch()):
        ref = naive_greedy(fn, budget, stop_zero, stop_neg)
        assert list(np.asarray(ref.order)) == list(np.asarray(r.order)), family
        assert np.array_equal(np.asarray(ref.gains), np.asarray(r.gains)), family

    t = _time(dispatch)
    return {
        "family": family,
        "B": B,
        "n": n,
        "budget": budget,
        "mesh": f"{mesh_shape[0]}x{mesh_shape[1]}",
        "wave_ms": round(t * 1e3, 2),
        "qps": round(B / t, 1),
    }


def run_serving_metrics(B=16, n=128, budget=8, family="fl"):
    """One front-door row: the structured serving metrics over a burst of
    ``B`` requests on the single-device server, with DETERMINISTIC
    backpressure — ``max_queue=B`` admits the burst, then 4 overflow submits
    are rejected, so ``rejections`` is exact and machine-independent (the
    bench_diff gate compares it exactly; ``queue_*`` dwell times ride along
    as informational columns and are skipped by the gate)."""
    from repro.core.optimizers.spec import SelectionSpec
    from repro.launch.serve import SelectionServer, ServerOverloaded

    fns = make_instances(B + 4, n, family)
    server = SelectionServer(max_queue=B)
    # one admitted request carries an (immediately-lapsed) deadline: flush
    # always starts later than 1 microsecond after submit, so
    # deadline_misses == 1, deterministically
    server.submit_spec(SelectionSpec(fns[0], budget, deadline_s=1e-6))
    rejected = 0
    for fn in fns[1:]:
        try:
            server.submit_spec(SelectionSpec(fn, budget))
        except ServerOverloaded:
            rejected += 1
    assert rejected == 4
    server.flush()
    snap = server.metrics.snapshot()
    return {
        "section": "serving_metrics",
        "family": family,
        "B": B,
        "n": n,
        "budget": budget,
        "mesh": "1x1",
        "requests": snap["counters"]["requests"],
        "waves": snap["counters"]["waves"],
        "rejections": snap["counters"]["rejections"],
        "deadline_misses": snap["counters"]["deadline_misses"],
        "queue_p50_ms": round(snap["queue_s"]["p50"] * 1e3, 2),
        "queue_p99_ms": round(snap["queue_s"]["p99"] * 1e3, 2),
    }


def _print_rows(title, rows):
    print(f"\n# {title}")
    print(
        f"{'family':>8s} {'B':>4s} {'n':>5s} {'k':>3s} {'mesh':>5s} "
        f"{'wave ms':>9s} {'q/s':>9s}"
    )
    for r in rows:
        print(
            f"{r['family']:>8s} {r['B']:4d} {r['n']:5d} {r['budget']:3d} "
            f"{r['mesh']:>5s} {r['wave_ms']:9.1f} {r['qps']:9.0f}"
        )


def _print_rows_metrics(title, rows):
    print(f"\n# {title}")
    print(
        f"{'family':>8s} {'B':>4s} {'req':>4s} {'waves':>5s} {'rej':>4s} "
        f"{'ddl miss':>8s} {'queue p50 ms':>13s} {'queue p99 ms':>13s}"
    )
    for r in rows:
        print(
            f"{r['family']:>8s} {r['B']:4d} {r['requests']:4d} "
            f"{r['waves']:5d} {r['rejections']:4d} {r['deadline_misses']:8d} "
            f"{r['queue_p50_ms']:13.2f} {r['queue_p99_ms']:13.2f}"
        )


def main(quick: bool = False, json_path: str | None = None):
    budget = 8
    # classic FL wave-size x mesh-shape sweep.  The quick cells are a strict
    # SUBSET of the full sweep, so `make serve-smoke`'s bench_diff of a
    # --quick run against the committed full snapshot compares real rows.
    fl_cells = (
        [(16, 128, (1, 1)), (16, 128, (4, 2))]
        if quick
        else [
            (B, n, shape)
            for n in (128, 256)
            for B in (16, 64)
            for shape in ((1, 1), (8, 1), (1, 8), (4, 2), (2, 4))
        ]
    )
    fl_rows = [run_cell(B, n, budget, shape) for B, n, shape in fl_cells]
    _print_rows("Serving wave throughput: wave size x mesh shape (batch x data)", fl_rows)

    # the function x backend serving matrix: every served family, single
    # device vs a 2x2 batch x data mesh
    families = ["sc", "psc", "dsum"] if quick else [f for f in FAMILIES if f != "fl"]
    fam_rows = [
        run_cell(16, 128, budget, shape, family=fam)
        for fam in families
        for shape in ((1, 1), (2, 2))
    ]
    _print_rows("Family breadth: every served family, 1x1 vs 2x2 mesh", fam_rows)

    # front-door metrics: queue time + deterministic rejection accounting
    metric_rows = [run_serving_metrics(budget=budget)]
    _print_rows_metrics("Serving front door: queue time and admission control",
                        metric_rows)

    rows = fl_rows + fam_rows + metric_rows
    best = max((r for r in rows if "qps" in r), key=lambda r: r["qps"])
    print(
        f"\nbest cell: {best['family']} B={best['B']} n={best['n']} "
        f"mesh={best['mesh']} -> {best['qps']:.0f} q/s"
    )
    if json_path:
        snapshot = {
            "bench": "serve_bench",
            "host": platform.machine(),
            "backend": jax.default_backend(),
            "devices": len(jax.devices()),
            "jax": jax.__version__,
            "rows": rows,
        }
        with open(json_path, "w") as f:
            json.dump(snapshot, f, indent=1)
        print(f"wrote {len(rows)} rows to {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="smoke sweep")
    ap.add_argument("--json", default=None, help="dump rows to this path")
    a = ap.parse_args()
    main(quick=a.quick, json_path=a.json)
