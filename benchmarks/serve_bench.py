"""Distributed batched serving throughput: wave size x mesh shape sweep.

Spawns 8 host-platform devices (XLA_FLAGS must be set before the first jax
import, so this module is its own entry point) and measures steady-state
wave throughput of the serving stack for a homogeneous FacilityLocation
workload across:

  - wave sizes B (requests coalesced per dispatch), and
  - mesh shapes (batch x data): how the wave is laid out over devices —
    1x1 is the single-device vmap engine; Bx1 shards only the batch axis;
    1xD shards only each instance's ground set; intermediate shapes do both.

Reported per cell: wall time per wave and queries/sec (best of 3 after a
compile warm-up).  Selections are asserted bit-identical to the sequential
loop before timing.

    PYTHONPATH=src python -m benchmarks.serve_bench          # full sweep
    PYTHONPATH=src python -m benchmarks.serve_bench --quick  # 2 cells
"""
from __future__ import annotations

import argparse
import os
import time

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402  (after the device-count env var)
import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    FacilityLocation,
    create_kernel,
    naive_greedy,
)
from repro.core.optimizers.batched import BatchedEngine  # noqa: E402


def make_instances(B, n, d=8, seed=0):
    rng = np.random.default_rng(seed)
    fns = []
    for _ in range(B):
        x = rng.normal(size=(n, d)).astype(np.float32)
        S = np.asarray(create_kernel(x, metric="euclidean"))
        fns.append(FacilityLocation.from_kernel(S))
    return fns


def _time(fn, reps=5):
    fn()  # warm-up / compile
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(reps):
            fn()
        best = min(best, (time.perf_counter() - t0) / reps)
    return best


def run_cell(B, n, budget, mesh_shape):
    """One (wave size, mesh shape) cell; returns the timing row."""
    fns = make_instances(B, n)
    if mesh_shape == (1, 1):
        engine = BatchedEngine(fns)  # single-device vmap engine
    else:
        mesh = jax.make_mesh(mesh_shape, ("batch", "data"))
        engine = BatchedEngine(fns, mesh=mesh)

    # correctness gate before timing: bit-identical to the sequential loop
    for fn, r in zip(fns, engine.maximize(budget, return_result=True)):
        ref = naive_greedy(fn, budget)
        assert list(np.asarray(ref.order)) == list(np.asarray(r.order))
        assert np.array_equal(np.asarray(ref.gains), np.asarray(r.gains))

    t = _time(lambda: engine.maximize(budget, return_result=True))
    return {
        "B": B,
        "n": n,
        "budget": budget,
        "mesh": f"{mesh_shape[0]}x{mesh_shape[1]}",
        "wave_ms": t * 1e3,
        "qps": B / t,
    }


def main(quick: bool = False):
    budget = 8
    cells = (
        [(32, 128, (1, 1)), (32, 128, (2, 2))]
        if quick
        else [
            (B, n, shape)
            for n in (128, 256)
            for B in (16, 64)
            for shape in ((1, 1), (8, 1), (1, 8), (4, 2), (2, 4))
        ]
    )
    rows = [run_cell(B, n, budget, shape) for B, n, shape in cells]

    print("\n# Serving wave throughput: wave size x mesh shape (batch x data)")
    print(f"{'B':>4s} {'n':>5s} {'k':>3s} {'mesh':>5s} {'wave ms':>9s} {'q/s':>9s}")
    for r in rows:
        print(
            f"{r['B']:4d} {r['n']:5d} {r['budget']:3d} {r['mesh']:>5s} "
            f"{r['wave_ms']:9.1f} {r['qps']:9.0f}"
        )
    meshes = {r["mesh"] for r in rows}
    best = max(rows, key=lambda r: r["qps"])
    print(
        f"\n{len(meshes)} mesh shapes; best cell: B={best['B']} n={best['n']} "
        f"mesh={best['mesh']} -> {best['qps']:.0f} q/s"
    )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="2-cell smoke sweep")
    a = ap.parse_args()
    main(quick=a.quick)
