"""Paper Table 2 reproduction: optimizer comparison on the 500-point /
10-cluster synthetic dataset (std 4), budget 10, FacilityLocation.

Reported:
  - wall time per optimizer on THIS hardware (CPU here; the paper ran C++
    on CPU — absolute numbers differ, the ordering is the claim)
  - marginal-gain evaluation counts: the hardware-independent cost metric
    (DESIGN §8.1) — naive >> stochastic > lazy-family, as in the paper
  - achieved objective value (all four must be within a few % of greedy)
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import (
    FacilityLocation,
    create_kernel,
    host_lazy_greedy,
    lazier_than_lazy_greedy,
    lazy_greedy,
    naive_greedy,
    stochastic_greedy,
)


def make_dataset(n=500, k=10, std=4.0, d=2, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-40, 40, size=(k, d))
    pts = centers[rng.integers(0, k, n)] + rng.normal(scale=std, size=(n, d))
    return pts.astype(np.float32)


def run(budget: int = 10):
    pts = make_dataset()
    S = np.asarray(create_kernel(pts, metric="euclidean"))
    fn = FacilityLocation.from_kernel(S)
    key = jax.random.PRNGKey(0)

    runners = {
        "NaiveGreedy": lambda: naive_greedy(fn, budget),
        "StochasticGreedy": lambda: stochastic_greedy(fn, budget, key, 0.01),
        "LazyGreedy": lambda: lazy_greedy(fn, budget),
        "LazierThanLazyGreedy": lambda: lazier_than_lazy_greedy(
            fn, budget, key, 0.01
        ),
    }
    rows = []
    for name, r in runners.items():
        res = jax.block_until_ready(r())  # compile
        t0 = time.perf_counter()
        for _ in range(3):
            res = jax.block_until_ready(r())
        dt = (time.perf_counter() - t0) / 3
        rows.append(
            {
                "optimizer": name,
                "ms_per_run": dt * 1e3,
                "gain_evals": int(res.n_evals),
                "objective": float(res.value),
            }
        )
    # the paper's faithful Minoux heap, host-side (evaluation-count reference)
    t0 = time.perf_counter()
    order, gains, n_evals = host_lazy_greedy(fn, budget)
    rows.append(
        {
            "optimizer": "LazyGreedy(host-heap, paper-faithful)",
            "ms_per_run": (time.perf_counter() - t0) * 1e3,
            "gain_evals": n_evals,
            "objective": float(sum(gains)),
        }
    )
    return rows


def main():
    rows = run()
    best = max(r["objective"] for r in rows)
    print("\n# Table 2 reproduction — optimizer comparison (500 pts, 10 clusters)")
    print(f"{'optimizer':38s} {'ms/run':>9s} {'gain evals':>11s} {'objective':>10s} {'vs best':>8s}")
    for r in rows:
        print(
            f"{r['optimizer']:38s} {r['ms_per_run']:9.1f} {r['gain_evals']:11d} "
            f"{r['objective']:10.2f} {r['objective'] / best:8.4f}"
        )
    return rows


if __name__ == "__main__":
    main()
