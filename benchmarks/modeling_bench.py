"""Paper Fig. 5 reproduction (quantified): FacilityLocation vs DisparitySum
modeling behaviour on the controlled 2D dataset with clusters + outliers.

Claims checked:
  - FL's selection is representative: low mean distance from every ground
    point to its nearest selected point; outliers picked late or never.
  - DisparitySum's selection is diverse: large min pairwise distance and it
    picks the outliers early.
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    DisparitySum,
    FacilityLocation,
    create_kernel,
    naive_greedy,
)


def make_dataset(seed=0):
    """~4 tight clusters + 3 outliers (mirrors the paper's 48-pt setup)."""
    rng = np.random.default_rng(seed)
    centers = np.array([[0, 0], [8, 0], [0, 8], [8, 8]], np.float32)
    pts = [
        centers[rng.integers(0, 4)] + rng.normal(scale=0.6, size=2)
        for _ in range(45)
    ]
    outliers = np.array([[20, 20], [-12, 16], [16, -12]], np.float32)
    data = np.concatenate([np.asarray(pts, np.float32), outliers])
    return data, list(range(45, 48))


def run(budget=10):
    data, outlier_idx = make_dataset()
    S = np.asarray(create_kernel(data, metric="euclidean"))
    D = np.sqrt(
        np.maximum(((data[:, None] - data[None, :]) ** 2).sum(-1), 0)
    ).astype(np.float32)

    fl = naive_greedy(FacilityLocation.from_kernel(S), budget, False, False)
    ds = naive_greedy(DisparitySum.from_distance(D), budget, False, False)
    sel_fl = [i for i, _ in fl.as_list()]
    sel_ds = [i for i, _ in ds.as_list()]

    def repr_cost(sel):
        return float(D[:, sel].min(axis=1).mean())

    def mean_pairwise(sel):
        sub = D[np.ix_(sel, sel)]
        return float(sub[~np.eye(len(sel), dtype=bool)].mean())

    def outlier_rank(sel):
        ranks = [sel.index(o) for o in outlier_idx if o in sel]
        return min(ranks) if ranks else None

    return {
        "fl": {
            "selection": sel_fl,
            "repr_cost": repr_cost(sel_fl),
            "mean_pairwise": mean_pairwise(sel_fl),
            "first_outlier_rank": outlier_rank(sel_fl),
        },
        "dsum": {
            "selection": sel_ds,
            "repr_cost": repr_cost(sel_ds),
            "mean_pairwise": mean_pairwise(sel_ds),
            "first_outlier_rank": outlier_rank(sel_ds),
        },
    }


def main():
    out = run()
    print("\n# Fig. 5 reproduction — FL vs DisparitySum behaviour (quantified)")
    print(f"{'function':12s} {'repr-cost↓':>11s} {'mean-pair-dist↑':>15s} {'first outlier pick':>20s}")
    for name in ("fl", "dsum"):
        r = out[name]
        rank = r["first_outlier_rank"]
        print(
            f"{name:12s} {r['repr_cost']:11.3f} {r['mean_pairwise']:15.3f} "
            f"{'step ' + str(rank) if rank is not None else 'never':>20s}"
        )
    assert out["fl"]["repr_cost"] < out["dsum"]["repr_cost"], "FL must represent better"
    assert out["dsum"]["mean_pairwise"] > out["fl"]["mean_pairwise"], "DSum must be more diverse"
    d_rank = out["dsum"]["first_outlier_rank"]
    f_rank = out["fl"]["first_outlier_rank"]
    assert d_rank is not None and d_rank <= 2, "DSum picks outliers first"
    assert f_rank is None or f_rank > d_rank, "FL defers outliers"
    print("claims: FL representative / DSum diverse+outliers-first — CONFIRMED")
    return out


if __name__ == "__main__":
    main()
