"""Roofline analysis (assignment: ROOFLINE ANALYSIS).

Reads the dry-run artifacts (results/dryrun/*.json) and derives, per
(arch x shape) on the single-pod mesh:

  compute term    = HLO_FLOPs_per_device / peak_FLOPs        [s]
  memory term     = HLO_bytes_per_device / HBM_bw            [s]
  collective term = collective_bytes_per_device / link_bw    [s]

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Caveats recorded with the table:
  * HLO flops/bytes come from two-depth UNROLLED probes extrapolated
    affinely to full depth (XLA cost_analysis ignores while bodies —
    launch/dryrun.py); flops inside the blockwise-attention inner scans are
    added analytically (attn_correction below).
  * XLA's bytes-accessed models CPU cache re-reads and overcounts HBM
    traffic ~5x on matmuls (measured); the memory term is therefore an
    upper bound. An analytic floor (params + activations once) is shown.
  * MODEL_FLOPS = 6*N_active*tokens (train) / 2*N_active*tokens (inference),
    per device; the ratio MODEL_FLOPS/HLO_FLOPs exposes remat/dispatch waste.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

RESULTS_DIR = os.environ.get("DRYRUN_DIR", "results/dryrun")


def _attn_flops_correction(cfg_d: dict, cell: dict, n_dev: int) -> float:
    """Analytic flops of attention-score/out einsums hidden inside the
    blockwise-attention scans (only active when seq > 8192 on attention
    layers). Train factor 4 (fwd + remat-fwd + 2 bwd); prefill factor 1."""
    from repro.configs.base import get_config

    cfg = get_config(cfg_d["arch"])
    seq = cell["seq_len"]
    if cell["kind"] == "decode" or seq <= 8192:
        return 0.0
    n_attn = sum(1 for l in range(cfg.n_layers) if cfg.is_attn_layer(l))
    if cfg.mla:
        hd = cfg.head_dim_ + cfg.rope_head_dim
        heads = cfg.n_heads
    elif cfg.n_heads:
        hd, heads = cfg.head_dim_, cfg.n_heads
    else:
        return 0.0
    B = cell["global_batch"]
    per_layer = 4.0 * B * heads * hd * seq * seq  # scores + out, fwd
    factor = 4.0 if cell["kind"] == "train" else 1.0
    return n_attn * per_layer * factor / n_dev


def _model_flops(rec: dict, cell: dict) -> float:
    from repro.configs.base import get_config

    n_act = rec.get("params_active") or 0
    tokens = cell["global_batch"] * (
        cell["seq_len"] if cell["kind"] in ("train", "prefill") else 1
    )
    per_tok = 6.0 * n_act if cell["kind"] == "train" else 2.0 * n_act
    total = per_tok * tokens
    if cell["kind"] == "prefill":
        # prefill computes logits for the LAST token only — remove the
        # lm-head share from all but one position per sequence
        cfg = get_config(rec["arch"])
        head = cfg.vocab * cfg.d_model
        total -= 2.0 * head * (tokens - cell["global_batch"])
    return total / rec["n_devices"]


def _analytic_mem_floor(rec: dict, cell: dict) -> float:
    """Unavoidable HBM bytes per device: params touched once per pass (bf16)
    + the full KV/SSM cache read for decode steps."""
    from repro.configs.base import get_config

    n_total = rec.get("params_total") or 0
    passes = 3.0 if cell["kind"] == "train" else 1.0
    total = n_total * 2.0 * passes
    if cell["kind"] == "decode":
        cfg = get_config(rec["arch"])
        per_tok = 0
        for l in range(cfg.n_layers):
            if cfg.family in ("ssm", "hybrid") and not cfg.is_attn_layer(l):
                continue  # SSM state is O(1), negligible vs KV
            if cfg.mla:
                per_tok += cfg.kv_lora_rank + cfg.rope_head_dim
            else:
                per_tok += 2 * cfg.n_kv_heads * cfg.head_dim_
        total += per_tok * 2.0 * cell["seq_len"] * cell["global_batch"]
    return total / rec["n_devices"]


def analyze(results_dir: str = RESULTS_DIR):
    from repro.launch.specs import SHAPES

    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*__single.json"))):
        rec = json.load(open(path))
        if rec["arch"] == "selection":
            continue
        cell_obj = SHAPES[rec["shape"]]
        cell = {
            "kind": cell_obj.kind,
            "seq_len": cell_obj.seq_len,
            "global_batch": cell_obj.global_batch,
        }
        if rec.get("flops_per_device") is None:
            continue
        corr = _attn_flops_correction(rec, cell, rec["n_devices"])
        flops = rec["flops_per_device"] + corr
        t_c = flops / PEAK_FLOPS
        t_m = rec["bytes_per_device"] / HBM_BW
        t_m_floor = _analytic_mem_floor(rec, cell) / HBM_BW
        t_x = rec["collectives"]["total"] / LINK_BW
        terms = {"compute": t_c, "memory": t_m, "collective": t_x}
        dominant = max(terms, key=terms.get)
        mf = _model_flops(rec, cell)
        # roofline fraction: the step's IDEAL time (useful flops at peak, or
        # the unavoidable HBM floor, whichever binds) over the modeled time
        ideal = max(mf / PEAK_FLOPS, t_m_floor)
        rows.append(
            {
                "arch": rec["arch"],
                "shape": rec["shape"],
                "compute_s": t_c,
                "memory_s": t_m,
                "memory_floor_s": t_m_floor,
                "collective_s": t_x,
                "dominant": dominant,
                "model_flops_per_dev": mf,
                "hlo_flops_per_dev": flops,
                "useful_ratio": mf / flops if flops else 0.0,
                "roofline_fraction": ideal / max(terms.values())
                if max(terms.values()) > 0
                else 0.0,
                "mem_temp_gb": rec["memory"].get("temp_size_in_bytes", 0) / 1e9,
                "attn_corr_share": corr / flops if flops else 0.0,
            }
        )
    return rows


RECO = {
    "compute": "raise useful-FLOP share (cut remat/dispatch overhead) or grow per-device batch",
    "memory": "fuse/relayout to cut HBM traffic; larger per-device tiles; bf16 intermediates",
    "collective": "reshard to cut weight gathers (bigger TP share), overlap collectives with compute, int8 gradient compression",
}


def main():
    rows = analyze()
    if not rows:
        print("no dry-run artifacts found — run: python -m repro.launch.dryrun --all")
        return []
    print("\n# Roofline — single-pod 16x16 (terms in ms/step per device)")
    hdr = f"{'arch':22s} {'shape':12s} {'compute':>9s} {'memory':>9s} {'mem-floor':>10s} {'collective':>11s} {'dominant':>10s} {'useful%':>8s} {'roofline%':>9s}"
    print(hdr)
    for r in rows:
        print(
            f"{r['arch']:22s} {r['shape']:12s} {1e3 * r['compute_s']:9.1f} "
            f"{1e3 * r['memory_s']:9.1f} {1e3 * r['memory_floor_s']:10.1f} "
            f"{1e3 * r['collective_s']:11.1f} {r['dominant']:>10s} "
            f"{100 * r['useful_ratio']:8.1f} {100 * r['roofline_fraction']:9.1f}"
        )
    print("\nrecommendations by dominant term:")
    for k, v in RECO.items():
        print(f"  {k:10s}: {v}")
    return rows


if __name__ == "__main__":
    main()
