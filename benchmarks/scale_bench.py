"""Dense vs matrix-free selection at scale: wall clock + peak similarity bytes.

The matrix-free path's claim (docs/functions.md, tests/test_matrix_free.py)
is that selection cost scales with the FEATURE bytes, not n² kernel bytes.
This bench records both sides per cell:

  - ``select_ms``  — wall time for one full greedy ``solve()`` (best of 3
    after a compile warm-up); noisy on shared boxes, diffed at a loose
    threshold by ``make scale-smoke``.
  - ``peak_bytes`` — the ANALYTIC peak similarity-storage footprint, exact
    and machine-independent (``tools/bench_diff.py`` compares it exactly
    and reports drift as a NOTE: a change means the memory shape changed):
      dense         n * n * 4             (the materialized float32 kernel)
      features      n * (d + TILE) * 4    (features + one streamed tile)
      features_rep  n * d * 4 + u * (d + TILE) * 4
      knn           n * k * 8             (int32 indices + float32 weights)

Paths: ``dense`` materializes the kernel; ``features`` is the symmetric
matrix-free objective (rows == candidates, O(n^2) similarity WORK per sweep
but O(n * TILE) memory); ``features_rep`` is how FL actually scales to
millions of points — ``u`` representative rows over all n candidates, so a
sweep is O(u * n) work; ``knn`` sweeps a sparse graph in O(n * k).  Dense
cells stop at n where n² fits comfortably; the matrix-free cells keep
going — that asymmetry IS the result.  At every n where both paths run, the
selections are asserted identical before timing.  ``--quick`` runs a strict
subset of the full sweep so ``make scale-smoke`` diffs real rows against the
committed ``benchmarks/BENCH_scale.json``.

    PYTHONPATH=src python -m benchmarks.scale_bench          # full sweep
    PYTHONPATH=src python -m benchmarks.scale_bench --quick  # smoke cells
    PYTHONPATH=src python -m benchmarks.scale_bench --json benchmarks/BENCH_scale.json
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    FacilityLocation,
    FacilityLocationMF,
    GraphCut,
    GraphCutMF,
    SelectionSpec,
    create_kernel,
    knn_from_features,
    solve,
)
from repro.core.sources import TILE  # noqa: E402

METRIC = "rbf"
D = 16
K = 32
U = 512  # representative rows for the features_rep path
LAM = 0.4


def _points(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, D)).astype(np.float32)


def _build(family, path, x):
    n = x.shape[0]
    if path == "dense":
        S = create_kernel(x, metric=METRIC)
        fn = (FacilityLocation.from_kernel(S) if family == "fl"
              else GraphCut.from_kernel(S, lam=LAM))
        return fn, n * n * 4
    if path == "features":
        fn = (FacilityLocationMF.from_features(x, metric=METRIC)
              if family == "fl"
              else GraphCutMF.from_features(x, lam=LAM, metric=METRIC))
        return fn, n * (D + TILE) * 4
    if path == "features_rep":
        # FL at true scale: u stride-sampled representative rows, all n
        # candidates — a sweep is O(u * n) work, O(u * TILE) live similarity
        assert family == "fl"
        rep = x[:: max(1, n // U)][:U]
        fn = FacilityLocationMF.from_features(rep, y=x, metric=METRIC)
        return fn, n * D * 4 + rep.shape[0] * (D + TILE) * 4
    src = knn_from_features(x, k=K, metric=METRIC)
    fn = (FacilityLocationMF(src=src, n=src.n_cols, use_kernel=False)
          if family == "fl"
          else GraphCutMF.from_knn(src.indices, src.weights, lam=LAM))
    return fn, n * K * 8


def _time(fn, reps=1):
    fn()  # warm-up / compile
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(reps):
            fn()
        best = min(best, (time.perf_counter() - t0) / reps)
    return best


def run_cell(family, path, n, budget):
    x = _points(n)
    fn, peak = _build(family, path, x)
    spec = SelectionSpec(fn, budget)

    def run():
        return jax.block_until_ready(solve(spec).gains)

    # parity gate before timing: at sizes where dense also fits, the
    # feature-backed selection must pick the same items (knn and
    # features_rep are different objectives — the sparsified kernel and the
    # representative-row subset — so they have no dense twin to gate on)
    if path == "features" and n <= 4096:
        fn_d, _ = _build(family, "dense", x)
        r_d, r_m = solve(SelectionSpec(fn_d, budget)), solve(spec)
        assert list(np.asarray(r_d.order)) == list(np.asarray(r_m.order)), (
            family, path, n)

    t = _time(run)
    return {
        "family": family,
        "path": path,
        "n": n,
        "budget": budget,
        "select_ms": round(t * 1e3, 2),
        "peak_bytes": peak,
    }


# full sweep: (family, path, n).  The quick cells are a strict subset so
# `make scale-smoke`'s diff of a --quick run compares real committed rows.
QUICK_CELLS = [
    ("fl", "dense", 2048),
    ("fl", "features", 2048),
    ("fl", "knn", 2048),
]
FULL_CELLS = QUICK_CELLS + [
    ("fl", "dense", 8192),
    ("fl", "features", 8192),
    ("fl", "features_rep", 262144),
    ("fl", "features_rep", 1048576),
    ("fl", "knn", 16384),
    ("gc", "dense", 2048),
    ("gc", "features", 2048),
    ("gc", "features", 8192),
]


def _print_rows(title, rows):
    print(f"\n# {title}")
    print(f"{'family':>6s} {'path':>8s} {'n':>8s} {'k':>3s} "
          f"{'select ms':>10s} {'peak MB':>9s}")
    for r in rows:
        print(f"{r['family']:>6s} {r['path']:>8s} {r['n']:8d} "
              f"{r['budget']:3d} {r['select_ms']:10.1f} "
              f"{r['peak_bytes'] / 1e6:9.1f}")


def main(quick: bool = False, json_path: str | None = None):
    budget = 16
    cells = QUICK_CELLS if quick else FULL_CELLS
    rows = [run_cell(family, path, n, budget) for family, path, n in cells]
    _print_rows("Dense vs matrix-free selection: wall clock + peak sim bytes",
                rows)
    big = max(rows, key=lambda r: r["n"])
    dense_equiv = big["n"] * big["n"] * 4
    print(f"\nlargest cell: {big['family']}/{big['path']} n={big['n']} "
          f"holds {big['peak_bytes'] / 1e6:.0f} MB where a dense kernel "
          f"would need {dense_equiv / 1e9:.0f} GB")
    if json_path:
        snapshot = {
            "bench": "scale_bench",
            "host": platform.machine(),
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "rows": rows,
        }
        with open(json_path, "w") as f:
            json.dump(snapshot, f, indent=1)
        print(f"wrote {len(rows)} rows to {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="smoke sweep")
    ap.add_argument("--json", default=None, help="dump rows to this path")
    a = ap.parse_args()
    main(quick=a.quick, json_path=a.json)
